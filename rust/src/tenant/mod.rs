//! Multi-tenant cost-aware provisioning (Memshare-style, Cidon et al.):
//! one shared elastic cluster fronting many applications with different
//! miss costs and traffic patterns.
//!
//! The paper's controller optimizes a single aggregate workload. Real
//! in-memory cache deployments are multi-tenant, and the dollars at stake
//! differ wildly per tenant — a miss that re-runs a pricey backend query
//! is worth orders of magnitude more than a miss on a batch scan. This
//! module adds the tenant dimension without giving up the paper's O(1)
//! request path:
//!
//! * [`TenantRegistry`] — per-tenant id, miss-cost multiplier, traffic
//!   class, Memshare-style byte reservation and optional miss-ratio SLO
//!   ([`TenantSpec`], [`TrafficClass`]).
//! * [`ControllerBank`] — one §4 stochastic-approximation
//!   [`VirtualCache`] per tenant. Each controller sees its tenant's
//!   *scaled* miss cost, so each timer `T_i` converges to that tenant's
//!   own storage/miss balance point.
//! * [`Arbiter`] — at each epoch boundary, folds the per-tenant shadow
//!   sizes into the shared cluster sizing decision: reserved floors first
//!   (Memshare's reserved-vs-pooled split), then the pooled capacity in
//!   descending miss-cost-weight order, so when the instance cap binds
//!   the squeeze lands on the tenants whose misses are cheapest.
//! * **Grant enforcement** (`scaler.enforce_grants`) — the arbiter's
//!   `granted_bytes` are *binding*, closed-loop, not merely reported:
//!   each epoch every grant (which already contains the tenant's reserved
//!   floor) becomes (a) a per-tenant **occupancy cap that binds on
//!   physical resident bytes**: the balancer feeds each tenant's cluster
//!   ledger row ([`EpochSizer::note_physical`]) and an insert is admitted
//!   only while `resident + size ≤ cap` (a constant-time compare per
//!   request); re-admissions of the tenant's virtually-resident set stay
//!   exempt (repair traffic its grant already covers), and a tenant found
//!   *over* its cap at an epoch boundary is brought back under it by
//!   **targeted shedding** of its own coldest entries
//!   ([`crate::cluster::Cluster::shed_tenant`]) rather than by refusing
//!   repair admissions; and
//!   (b) a per-tenant **TTL clamp**: a tenant whose controller wants more
//!   memory than its grant has its timer projected onto
//!   `[T_min, T · granted/demand]`, so it converges to the largest
//!   affordable timer instead of thrashing above it. A **feedback term**
//!   escalates a tenant's grant priority (weight × boost, ×2 per epoch up
//!   to 64×) while its *measured* physical miss ratio exceeds its
//!   configured `slo_miss_ratio`, and decays once compliant. With
//!   enforcement off (the default) grants remain reporting-only and the
//!   request path is bit-for-bit the pre-enforcement one.
//! * [`TenantTtlSizer`] — the [`EpochSizer`] gluing it all together;
//!   [`crate::balancer::Balancer`] dispatches each request's shadow
//!   update (and admission verdict) through it via the request's tenant
//!   id, and feeds physical outcomes back for the SLO tracker.
//!
//! Physical placement lives in [`crate::placement`]: by default the
//! balancer routes on `(tenant, key)` by folding the tenant into the
//! hash-slot key ([`scoped_object`]), so tenants share instances but
//! never collide; the `hash_slot_pinned` and `slab_partition` policies
//! additionally confine tenants to instance subsets or Memshare-style
//! per-instance byte partitions sized from this module's grants.

#![warn(missing_docs)]

use crate::config::{Config, ControllerConfig, CostConfig, ScalerConfig};
use crate::scaler::{EpochSizer, PolicyWork};
use crate::trace::Request;
use crate::vcache::VirtualCache;
use crate::{ObjectId, TenantId, TimeUs};

/// Grant-priority escalation per epoch in SLO violation (and the decay
/// factor once compliant). Public so the sharded front can replicate the
/// window arithmetic bit-for-bit.
pub const SLO_BOOST_STEP: f64 = 2.0;
/// Ceiling on the SLO escalation factor.
pub const SLO_BOOST_MAX: f64 = 64.0;

/// Drain bound K: a retiring tenant's residents must reach zero within
/// this many epoch boundaries (the balancer sheds the whole ledger row at
/// every boundary while the tenant drains; strict-LRU stores clear in
/// one, the bound leaves headroom for best-effort stores). Pinned by the
/// `tenant_churn` property suite and the `exp fig13` smoke test.
pub const MAX_DRAIN_EPOCHS: u32 = 4;

/// Where a tenant stands in its online lifecycle.
///
/// ```text
/// Admitted ──first request──▶ Active ──RETIRE──▶ Draining ──drained──▶ Retired
///     ▲                                                                  │
///     └───────────────────────── re-ADMIT ──────────────────────────────┘
/// ```
///
/// `Admitted` tenants are registered (explicitly via
/// [`ControllerBank::admit_tenant`], lazily by their first request, or
/// from the `[tenantN]` config roster) but have not served traffic yet.
/// A `Draining` tenant's controller has left the bank (no shadow updates,
/// no grants, no admissions); its residents are shed at epoch boundaries
/// until the ledger row reaches zero, at which point it becomes `Retired`
/// and its bill is reconciled ([`crate::cost::CostTracker::close_tenant`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LifecycleState {
    /// Registered, no traffic served yet.
    Admitted,
    /// Serving traffic; the arbiter grants it capacity.
    Active,
    /// Retirement requested; residents being reclaimed.
    Draining,
    /// Fully drained; bill reconciled. Terminal until re-admission.
    Retired,
}

impl LifecycleState {
    /// Stable lowercase name (serve protocol / CSV artifacts).
    pub fn as_str(self) -> &'static str {
        match self {
            LifecycleState::Admitted => "admitted",
            LifecycleState::Active => "active",
            LifecycleState::Draining => "draining",
            LifecycleState::Retired => "retired",
        }
    }
}

/// One tenant's lifecycle record: the state plus the transition
/// timestamps an operator (or `exp fig13`) needs to audit a churn run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Lifecycle {
    state: LifecycleState,
    /// When the tenant was (last) admitted.
    pub admitted_at: TimeUs,
    /// When it served its first request after (re-)admission.
    pub activated_at: Option<TimeUs>,
    /// When retirement was requested (drain start).
    pub retire_requested_at: Option<TimeUs>,
    /// When the drain completed and the bill was reconciled.
    pub retired_at: Option<TimeUs>,
    /// Epoch boundaries spent draining (≤ [`MAX_DRAIN_EPOCHS`]).
    pub drain_epochs: u32,
}

impl Lifecycle {
    /// A freshly admitted lifecycle.
    pub fn admitted_at(now: TimeUs) -> Lifecycle {
        Lifecycle {
            state: LifecycleState::Admitted,
            admitted_at: now,
            activated_at: None,
            retire_requested_at: None,
            retired_at: None,
            drain_epochs: 0,
        }
    }

    /// Current state.
    pub fn state(&self) -> LifecycleState {
        self.state
    }

    /// Whether the tenant participates in arbitration (shadow updates,
    /// demands, grants).
    pub fn participates(&self) -> bool {
        matches!(self.state, LifecycleState::Admitted | LifecycleState::Active)
    }

    fn activate(&mut self, now: TimeUs) {
        if self.state == LifecycleState::Admitted {
            self.state = LifecycleState::Active;
            self.activated_at = Some(now);
        }
    }

    fn begin_drain(&mut self, now: TimeUs) {
        self.state = LifecycleState::Draining;
        self.retire_requested_at = Some(now);
        self.drain_epochs = 0;
    }

    fn finish_drain(&mut self, now: TimeUs) {
        self.state = LifecycleState::Retired;
        self.retired_at = Some(now);
    }
}

/// What a mid-run `ADMIT` actually did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    /// A brand-new tenant was admitted.
    Admitted,
    /// A live tenant's spec (reservation, SLO, weight) was updated.
    Updated,
    /// A retired tenant was re-admitted with a fresh lifecycle.
    Readmitted,
}

impl AdmitOutcome {
    /// Stable lowercase name (serve protocol responses).
    pub fn as_str(self) -> &'static str {
        match self {
            AdmitOutcome::Admitted => "admitted",
            AdmitOutcome::Updated => "updated",
            AdmitOutcome::Readmitted => "readmitted",
        }
    }
}

/// Traffic class of a tenant — a coarse service-level label, reported in
/// ledgers and usable by operators to pick miss-cost multipliers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Latency-sensitive request/response traffic (misses are expensive).
    Interactive,
    /// Ordinary web/CDN traffic.
    Standard,
    /// Throughput-oriented batch/scan traffic (misses are cheap).
    Bulk,
}

impl TrafficClass {
    /// Stable lowercase name (config files, reports).
    pub fn as_str(self) -> &'static str {
        match self {
            TrafficClass::Interactive => "interactive",
            TrafficClass::Standard => "standard",
            TrafficClass::Bulk => "bulk",
        }
    }

    /// Parse the [`Self::as_str`] form back.
    pub fn parse(s: &str) -> crate::Result<TrafficClass> {
        Ok(match s {
            "interactive" => TrafficClass::Interactive,
            "standard" => TrafficClass::Standard,
            "bulk" => TrafficClass::Bulk,
            other => anyhow::bail!("unknown traffic class {other} (interactive|standard|bulk)"),
        })
    }
}

/// Static description of one tenant.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Compact tenant identifier carried by requests.
    pub id: TenantId,
    /// Display name (reports, config sections).
    pub name: String,
    /// Multiplier applied to the catalog per-miss cost for this tenant
    /// (its misses cost `multiplier × m_o` dollars).
    pub miss_cost_multiplier: f64,
    /// Coarse service-level label.
    pub class: TrafficClass,
    /// Memshare-style reservation: bytes of the shared cluster guaranteed
    /// to this tenant even under contention (`[tenantN] reserved_mb`).
    /// The reservation is both a grant floor in the [`Arbiter`] and an
    /// admission-budget floor under enforcement. 0 = fully pooled.
    pub reserved_bytes: u64,
    /// Miss-ratio service-level objective (`[tenantN] slo_miss_ratio`).
    /// While the tenant's measured physical miss ratio exceeds this
    /// target, its grant priority escalates epoch over epoch. `None` =
    /// best-effort tenant.
    pub slo_miss_ratio: Option<f64>,
}

impl TenantSpec {
    /// A default spec: 1× miss cost, standard class, no reservation, no
    /// SLO.
    pub fn new(id: TenantId, name: impl Into<String>) -> TenantSpec {
        TenantSpec {
            id,
            name: name.into(),
            miss_cost_multiplier: 1.0,
            class: TrafficClass::Standard,
            reserved_bytes: 0,
            slo_miss_ratio: None,
        }
    }

    /// Set the miss-cost multiplier.
    pub fn with_multiplier(mut self, m: f64) -> TenantSpec {
        self.miss_cost_multiplier = m;
        self
    }

    /// Set the traffic class.
    pub fn with_class(mut self, class: TrafficClass) -> TenantSpec {
        self.class = class;
        self
    }

    /// Set the Memshare-style byte reservation.
    pub fn with_reserved_bytes(mut self, bytes: u64) -> TenantSpec {
        self.reserved_bytes = bytes;
        self
    }

    /// Set the miss-ratio SLO target.
    pub fn with_slo_miss_ratio(mut self, target: f64) -> TenantSpec {
        self.slo_miss_ratio = Some(target);
        self
    }
}

impl Default for TenantSpec {
    fn default() -> Self {
        TenantSpec::new(0, "default")
    }
}

/// The set of known tenants. Lookup is a linear scan — registries hold a
/// handful of tenants, and the hot path goes through [`ControllerBank`]'s
/// dense index instead.
#[derive(Debug, Clone, Default)]
pub struct TenantRegistry {
    specs: Vec<TenantSpec>,
}

impl TenantRegistry {
    /// An empty registry.
    pub fn new() -> TenantRegistry {
        TenantRegistry { specs: Vec::new() }
    }

    /// A registry holding only the default tenant 0 (the single-workload
    /// configuration every pre-tenant trace maps onto).
    pub fn single_tenant() -> TenantRegistry {
        TenantRegistry { specs: vec![TenantSpec::default()] }
    }

    /// Build from specs; a later spec with a duplicate id replaces the
    /// earlier one.
    pub fn from_specs(specs: impl IntoIterator<Item = TenantSpec>) -> TenantRegistry {
        let mut reg = TenantRegistry::new();
        for s in specs {
            reg.register(s);
        }
        reg
    }

    /// Register (or replace, by id) one spec.
    pub fn register(&mut self, spec: TenantSpec) {
        match self.specs.iter_mut().find(|s| s.id == spec.id) {
            Some(slot) => *slot = spec,
            None => self.specs.push(spec),
        }
    }

    /// Number of registered tenants.
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// Whether no tenant is registered.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Iterate the registered specs in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &TenantSpec> {
        self.specs.iter()
    }

    /// The spec registered under `id`, if any.
    pub fn get(&self, id: TenantId) -> Option<&TenantSpec> {
        self.specs.iter().find(|s| s.id == id)
    }

    /// Miss-cost multiplier for `id` (1.0 for unknown tenants).
    pub fn multiplier(&self, id: TenantId) -> f64 {
        self.get(id).map(|s| s.miss_cost_multiplier).unwrap_or(1.0)
    }

    /// Reserved bytes for `id` (0 for unknown tenants).
    pub fn reserved_bytes(&self, id: TenantId) -> u64 {
        self.get(id).map(|s| s.reserved_bytes).unwrap_or(0)
    }
}

/// Fold a tenant id into an object id so tenants sharing physical
/// instances never collide on keys, while tenant 0 (single-workload
/// traces) keeps its ids — and therefore its routing — bit-for-bit
/// unchanged. XOR with a per-tenant mixed constant is a bijection per
/// tenant, so it preserves each tenant's key-space structure.
#[inline]
pub fn scoped_object(tenant: TenantId, obj: ObjectId) -> ObjectId {
    if tenant == 0 {
        obj
    } else {
        obj ^ crate::mix64(tenant as u64)
    }
}

/// Windowed per-tenant SLO tracker: measures the physical miss ratio of
/// the closing epoch and escalates/decays the tenant's grant-priority
/// boost against its configured target.
#[derive(Debug, Clone)]
struct SloState {
    target: Option<f64>,
    epoch_hits: u64,
    epoch_misses: u64,
    /// Miss ratio of the last closed epoch that carried traffic.
    measured: Option<f64>,
    /// Grant-priority escalation factor (1.0 = compliant/untracked).
    boost: f64,
}

impl SloState {
    fn new(target: Option<f64>) -> SloState {
        SloState { target, epoch_hits: 0, epoch_misses: 0, measured: None, boost: 1.0 }
    }

    #[inline]
    fn record(&mut self, hit: bool) {
        if hit {
            self.epoch_hits += 1;
        } else {
            self.epoch_misses += 1;
        }
    }

    /// Close the epoch's measurement window and update the boost. Quiet
    /// epochs (no traffic) decay the boost rather than escalating on
    /// stale measurements.
    fn close_epoch(&mut self) {
        let total = self.epoch_hits + self.epoch_misses;
        let fresh = if total > 0 {
            Some(self.epoch_misses as f64 / total as f64)
        } else {
            None
        };
        if fresh.is_some() {
            self.measured = fresh;
        }
        self.epoch_hits = 0;
        self.epoch_misses = 0;
        if let Some(target) = self.target {
            match fresh {
                Some(m) if m > target => {
                    self.boost = (self.boost * SLO_BOOST_STEP).min(SLO_BOOST_MAX);
                }
                _ => {
                    self.boost = (self.boost / SLO_BOOST_STEP).max(1.0);
                }
            }
        }
    }
}

/// One tenant's controller plus its enforcement and lifecycle state.
struct TenantSlot {
    id: TenantId,
    vc: VirtualCache,
    slo: SloState,
    life: Lifecycle,
    /// Occupancy cap in force, bytes of *physical residency* (the
    /// tenant's `granted_bytes`, which already contains its reserved
    /// floor); `u64::MAX` before the first epoch decision or when
    /// enforcement is off.
    cap_bytes: u64,
    /// Physical resident bytes, as last reported by the balancer
    /// ([`EpochSizer::note_physical`] mirrors the cluster ledger row).
    physical_bytes: u64,
    /// Bytes admitted (inserted on miss, outside the shadow set) during
    /// the open epoch — diagnostic insert-volume counter.
    epoch_admitted_bytes: u64,
    /// Cumulative admissions refused by the cap.
    denied: u64,
    /// Shadow demand / grant from the most recent epoch decision.
    last_demand: u64,
    last_grant: u64,
    /// Whether any epoch decision has been taken yet.
    decided: bool,
}

/// Read-only snapshot of one tenant's enforcement state (the `SLO`
/// serve command and the [`crate::engine::SloProbe`] surface this).
#[derive(Debug, Clone, PartialEq)]
pub struct TenantEnforcement {
    /// The tenant this row describes.
    pub tenant: TenantId,
    /// Shadow demand at the last epoch decision, bytes.
    pub demand_bytes: u64,
    /// Bytes granted at the last epoch decision.
    pub granted_bytes: u64,
    /// Whether an epoch decision has been taken yet.
    pub decided: bool,
    /// Whether grants are binding (`scaler.enforce_grants`).
    pub enforced: bool,
    /// Occupancy cap in force, binding on physical resident bytes.
    pub cap_bytes: Option<u64>,
    /// Memshare-style reserved floor from the tenant's spec.
    pub reserved_bytes: u64,
    /// Physical resident bytes as last reported by the balancer (the
    /// cluster ledger row feeding the cap comparison).
    pub physical_bytes: u64,
    /// Bytes admitted (inserted outside the shadow set) in the open epoch.
    pub admitted_epoch_bytes: u64,
    /// Cumulative admissions refused by the cap.
    pub denied_admissions: u64,
    /// TTL clamp in force on this tenant's controller, seconds.
    pub ttl_clamp_secs: Option<f64>,
    /// Configured miss-ratio SLO.
    pub slo_miss_ratio: Option<f64>,
    /// Measured physical miss ratio of the last closed epoch with traffic.
    pub measured_miss_ratio: Option<f64>,
    /// Grant-priority escalation factor (1.0 = compliant/untracked).
    pub boost: f64,
}

impl TenantEnforcement {
    /// Whether the last measurement violates the configured SLO.
    pub fn in_violation(&self) -> bool {
        matches!(
            (self.slo_miss_ratio, self.measured_miss_ratio),
            (Some(target), Some(m)) if m > target
        )
    }
}

/// One §4 virtual-TTL-cache controller per tenant, with O(1) dispatch by
/// tenant id (dense index vector; unknown tenants are admitted lazily
/// with default cost), plus the per-tenant enforcement state (occupancy
/// cap, admission budget, SLO tracker).
pub struct ControllerBank {
    ctrl: ControllerConfig,
    /// Base (multiplier-1) cost catalog.
    cost: CostConfig,
    registry: TenantRegistry,
    /// Tenant slots in registration order.
    slots: Vec<TenantSlot>,
    /// tenant id → slot index (`u32::MAX` = absent), grown on demand.
    index: Vec<u32>,
    /// Tenants whose drain completed since the last
    /// [`ControllerBank::take_retired`] call (billing-reconciliation
    /// queue for the engine).
    newly_retired: Vec<TenantId>,
}

impl ControllerBank {
    /// One controller per registry spec, each seeing its tenant's scaled
    /// miss cost.
    pub fn new(ctrl: &ControllerConfig, cost: CostConfig, registry: TenantRegistry) -> Self {
        let mut bank = ControllerBank {
            ctrl: ctrl.clone(),
            cost,
            registry: TenantRegistry::new(),
            slots: Vec::new(),
            index: Vec::new(),
            newly_retired: Vec::new(),
        };
        for spec in registry.iter() {
            bank.admit(spec.clone());
        }
        bank
    }

    /// Per-tenant cost view: the miss side is scaled by the tenant's
    /// multiplier, which is what makes each controller converge to its
    /// own `T_i` (eq. 7's corrections are `λ̂·m_i − c_i`).
    fn scaled_cost(&self, multiplier: f64) -> CostConfig {
        let mut c = self.cost.clone();
        c.miss_cost_dollars *= multiplier;
        c
    }

    fn admit(&mut self, spec: TenantSpec) {
        self.admit_at(spec, 0);
    }

    fn admit_at(&mut self, spec: TenantSpec, now: TimeUs) {
        let vc = VirtualCache::new(&self.ctrl, self.scaled_cost(spec.miss_cost_multiplier));
        let slot = self.slots.len() as u32;
        let id = spec.id as usize;
        if self.index.len() <= id {
            self.index.resize(id + 1, u32::MAX);
        }
        self.index[id] = slot;
        self.slots.push(TenantSlot {
            id: spec.id,
            vc,
            slo: SloState::new(spec.slo_miss_ratio),
            life: Lifecycle::admitted_at(now),
            cap_bytes: u64::MAX,
            physical_bytes: 0,
            epoch_admitted_bytes: 0,
            denied: 0,
            last_demand: 0,
            last_grant: 0,
            decided: false,
        });
        self.registry.register(spec);
    }

    /// Admit (or update) a tenant mid-run — the serve protocol's `ADMIT`
    /// and the trace event lane land here.
    ///
    /// * Unknown tenant → fresh slot in [`LifecycleState::Admitted`].
    /// * [`LifecycleState::Retired`] tenant → re-admission: a fresh
    ///   controller, SLO tracker and lifecycle; the cumulative cost
    ///   ledger keeps its history (the closed lifetime was already
    ///   reconciled).
    /// * Live (`Admitted`/`Active`) tenant → spec update: registry row,
    ///   SLO target and reservation change; the controller keeps its
    ///   trajectory.
    /// * [`LifecycleState::Draining`] tenant → error: the drain must
    ///   finish (and the bill reconcile) before re-admission.
    pub fn admit_tenant(&mut self, spec: TenantSpec, now: TimeUs) -> crate::Result<AdmitOutcome> {
        let idx = self.index.get(spec.id as usize).copied().unwrap_or(u32::MAX);
        if idx == u32::MAX {
            self.admit_at(spec, now);
            return Ok(AdmitOutcome::Admitted);
        }
        let scaled = self.scaled_cost(spec.miss_cost_multiplier);
        let slo = spec.slo_miss_ratio;
        let ctrl = self.ctrl.clone();
        let slot = &mut self.slots[idx as usize];
        match slot.life.state() {
            LifecycleState::Draining => {
                anyhow::bail!("tenant {} is draining; retire must finish first", spec.id)
            }
            LifecycleState::Retired => {
                slot.vc = VirtualCache::new(&ctrl, scaled);
                slot.slo = SloState::new(slo);
                slot.life = Lifecycle::admitted_at(now);
                slot.cap_bytes = u64::MAX;
                slot.physical_bytes = 0;
                slot.epoch_admitted_bytes = 0;
                slot.denied = 0;
                slot.last_demand = 0;
                slot.last_grant = 0;
                slot.decided = false;
                self.registry.register(spec);
                Ok(AdmitOutcome::Readmitted)
            }
            LifecycleState::Admitted | LifecycleState::Active => {
                slot.slo.target = slo;
                self.registry.register(spec);
                Ok(AdmitOutcome::Updated)
            }
        }
    }

    /// Begin retiring a tenant: its controller leaves the bank (no more
    /// shadow updates, demands or grants) and the balancer sheds its
    /// residents at the following epoch boundaries. Errors on unknown,
    /// already-draining and already-retired tenants.
    pub fn retire_tenant(&mut self, tenant: TenantId, now: TimeUs) -> crate::Result<()> {
        let idx = self.index.get(tenant as usize).copied().unwrap_or(u32::MAX);
        anyhow::ensure!(idx != u32::MAX, "unknown tenant {tenant}");
        let scaled = self.scaled_cost(self.registry.multiplier(tenant));
        let ctrl = self.ctrl.clone();
        let slot = &mut self.slots[idx as usize];
        match slot.life.state() {
            LifecycleState::Draining => anyhow::bail!("tenant {tenant} is already draining"),
            LifecycleState::Retired => anyhow::bail!("tenant {tenant} is already retired"),
            LifecycleState::Admitted | LifecycleState::Active => {}
        }
        slot.life.begin_drain(now);
        // The controller leaves the bank: drop its shadow state so the
        // aggregate demand shrinks immediately.
        slot.vc = VirtualCache::new(&ctrl, scaled);
        slot.cap_bytes = u64::MAX;
        Ok(())
    }

    /// Tenants currently draining (the balancer sheds these to zero at
    /// each epoch boundary).
    pub fn draining(&self) -> Vec<TenantId> {
        self.slots
            .iter()
            .filter(|s| s.life.state() == LifecycleState::Draining)
            .map(|s| s.id)
            .collect()
    }

    /// The balancer reports a draining tenant's residents reached zero:
    /// transition to [`LifecycleState::Retired`] and queue it for billing
    /// reconciliation.
    pub fn note_drained(&mut self, tenant: TenantId, now: TimeUs) {
        let idx = self.index.get(tenant as usize).copied().unwrap_or(u32::MAX);
        if idx == u32::MAX {
            return;
        }
        let slot = &mut self.slots[idx as usize];
        if slot.life.state() == LifecycleState::Draining {
            slot.life.finish_drain(now);
            self.newly_retired.push(tenant);
        }
    }

    /// Drain the queue of tenants whose retirement completed since the
    /// last call (the engine reconciles their bills).
    pub fn take_retired(&mut self) -> Vec<TenantId> {
        std::mem::take(&mut self.newly_retired)
    }

    /// Count one epoch boundary against every draining tenant (the ≤ K
    /// drain bound of [`MAX_DRAIN_EPOCHS`]).
    fn note_epoch_boundary(&mut self) {
        for s in &mut self.slots {
            if s.life.state() == LifecycleState::Draining {
                s.life.drain_epochs += 1;
            }
        }
    }

    /// Lifecycle record of one tenant (`None` if never admitted).
    pub fn lifecycle_of(&self, tenant: TenantId) -> Option<Lifecycle> {
        let idx = self.index.get(tenant as usize).copied()?;
        if idx == u32::MAX {
            return None;
        }
        Some(self.slots[idx as usize].life)
    }

    /// Every tenant's lifecycle record, in registration order.
    pub fn lifecycle_rows(&self) -> Vec<(TenantId, Lifecycle)> {
        self.slots.iter().map(|s| (s.id, s.life)).collect()
    }

    /// The bank's registry view (roster + lazily admitted strays).
    pub fn registry(&self) -> &TenantRegistry {
        &self.registry
    }

    /// Number of tenant slots (every lifecycle state included).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the bank holds no tenant slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The slot for `tenant`, creating one (default spec, multiplier 1.0)
    /// the first time an unregistered tenant shows up.
    #[inline]
    fn slot_mut(&mut self, tenant: TenantId) -> &mut TenantSlot {
        let id = tenant as usize;
        let slot = self.index.get(id).copied().unwrap_or(u32::MAX);
        let slot = if slot == u32::MAX {
            self.admit(TenantSpec::new(tenant, format!("tenant{tenant}")));
            self.slots.len() as u32 - 1
        } else {
            slot
        };
        &mut self.slots[slot as usize]
    }

    /// The controller for `tenant`, creating one (default spec, multiplier
    /// 1.0) the first time an unregistered tenant shows up.
    #[inline]
    pub fn controller_mut(&mut self, tenant: TenantId) -> &mut VirtualCache {
        &mut self.slot_mut(tenant).vc
    }

    /// The controller of `tenant`, if one exists.
    pub fn get(&self, tenant: TenantId) -> Option<&VirtualCache> {
        let slot = self.index.get(tenant as usize).copied()?;
        if slot == u32::MAX {
            return None;
        }
        Some(&self.slots[slot as usize].vc)
    }

    /// Iterate `(tenant, controller)` pairs in registration order.
    pub fn iter(&self) -> impl Iterator<Item = (TenantId, &VirtualCache)> {
        self.slots.iter().map(|s| (s.id, &s.vc))
    }

    /// Run expiry (and any pending controller updates) on every tenant.
    pub fn expire_all(&mut self, now: TimeUs) {
        for s in &mut self.slots {
            s.vc.expire(now);
        }
    }

    /// Sum of per-tenant virtual sizes, bytes.
    pub fn total_vsize(&self) -> u64 {
        self.slots.iter().map(|s| s.vc.vsize()).sum()
    }

    /// `(tenant, T_i seconds)` for every tenant.
    pub fn ttls(&self) -> Vec<(TenantId, f64)> {
        self.slots.iter().map(|s| (s.id, s.vc.ttl_secs())).collect()
    }

    /// Record a served request's physical outcome: SLO measurement, and —
    /// on admitted misses outside the shadow set — the epoch's admitted
    /// insert volume (diagnostic; the binding bound is the physical
    /// resident-byte cap checked in `on_request`). Shadow-hit
    /// re-admissions are repair traffic already counted by the demand
    /// estimator that produced the grant, so they are exempt. Denials
    /// that suppressed an insert (`!hit && !admitted`) are counted.
    #[inline]
    fn record_served(
        &mut self,
        tenant: TenantId,
        hit: bool,
        admitted: bool,
        shadow_hit: bool,
        size: u64,
    ) {
        let slot = self.slot_mut(tenant);
        slot.slo.record(hit);
        if !hit {
            if !admitted {
                // Only cap refusals count: a draining/retired tenant's
                // suppressed inserts are retirement semantics, not the
                // occupancy cap binding.
                if slot.life.participates() {
                    slot.denied += 1;
                }
            } else if !shadow_hit {
                slot.epoch_admitted_bytes = slot.epoch_admitted_bytes.saturating_add(size);
            }
        }
    }

    /// Close every tenant's SLO measurement window and reset the
    /// admission budgets for the next epoch.
    fn close_epoch_slo(&mut self) {
        for s in &mut self.slots {
            s.slo.close_epoch();
            s.epoch_admitted_bytes = 0;
        }
    }

    /// Per-tenant `(demand, reserved, weight)` rows for the arbiter; the
    /// weight is the miss-cost multiplier escalated by the SLO boost.
    /// Draining and retired tenants have left the bank: they place no
    /// demand and hold no reservation.
    fn demands(&self) -> Vec<TenantDemand> {
        self.slots
            .iter()
            .filter(|s| s.life.participates())
            .map(|s| TenantDemand {
                tenant: s.id,
                demand_bytes: s.vc.vsize(),
                reserved_bytes: self.registry.reserved_bytes(s.id),
                weight: self.registry.multiplier(s.id) * s.slo.boost,
            })
            .collect()
    }

    /// Apply one epoch grant to its tenant: record it, and under
    /// enforcement convert it into the occupancy cap (admission budget)
    /// and the TTL clamp.
    fn apply_grant(&mut self, a: &TenantAllocation, enforce: bool) {
        let slot = self.slot_mut(a.tenant);
        slot.last_demand = a.demand_bytes;
        slot.last_grant = a.granted_bytes;
        slot.decided = true;
        if !enforce {
            slot.cap_bytes = u64::MAX;
            return;
        }
        // The grant already contains the (possibly proportionally scaled)
        // reserved floor — flooring at the raw reservation here would let
        // oversubscribed reservations admit past cluster capacity.
        slot.cap_bytes = a.granted_bytes;
        if a.demand_bytes > a.granted_bytes {
            // The grant was trimmed below the controller's demand: clamp
            // the timer to the largest affordable value. vsize ≈ rate·T·s̄
            // is linear in T, so T·granted/demand is the first-order
            // affordable timer; repeated epochs converge geometrically.
            let frac = a.granted_bytes as f64 / a.demand_bytes as f64;
            let affordable = slot.vc.ttl_secs() * frac;
            slot.vc.set_ttl_cap_secs(affordable);
        } else {
            slot.vc.clear_ttl_cap();
        }
    }

    /// Enforcement snapshot for every *participating* tenant slot
    /// (draining/retired tenants hold no grants — in particular the
    /// balancer must not re-pin placement from their stale rows).
    fn enforcement_rows(&self, enforce: bool) -> Vec<TenantEnforcement> {
        self.slots
            .iter()
            .filter(|s| s.life.participates())
            .map(|s| TenantEnforcement {
                tenant: s.id,
                demand_bytes: s.last_demand,
                granted_bytes: s.last_grant,
                decided: s.decided,
                enforced: enforce,
                cap_bytes: if s.cap_bytes == u64::MAX { None } else { Some(s.cap_bytes) },
                reserved_bytes: self.registry.reserved_bytes(s.id),
                physical_bytes: s.physical_bytes,
                admitted_epoch_bytes: s.epoch_admitted_bytes,
                denied_admissions: s.denied,
                ttl_clamp_secs: s.vc.ttl_cap_secs(),
                slo_miss_ratio: s.slo.target,
                measured_miss_ratio: s.slo.measured,
                boost: s.slo.boost,
            })
            .collect()
    }
}

/// One tenant's input row to an epoch arbitration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantDemand {
    /// The demanding tenant.
    pub tenant: TenantId,
    /// Shadow (virtual cache) demand at the epoch boundary, bytes.
    pub demand_bytes: u64,
    /// Memshare-style reserved floor, bytes.
    pub reserved_bytes: u64,
    /// Miss-cost weight (multiplier × SLO boost) for contention ordering.
    pub weight: f64,
}

impl TenantDemand {
    /// A demand row with no reservation.
    pub fn new(tenant: TenantId, demand_bytes: u64, weight: f64) -> TenantDemand {
        TenantDemand { tenant, demand_bytes, reserved_bytes: 0, weight }
    }

    /// Set the reserved floor.
    pub fn with_reserved(mut self, bytes: u64) -> TenantDemand {
        self.reserved_bytes = bytes;
        self
    }
}

/// One tenant's share of an epoch sizing decision.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantAllocation {
    /// The granted tenant.
    pub tenant: TenantId,
    /// Shadow (virtual cache) demand at the epoch boundary, bytes.
    pub demand_bytes: u64,
    /// Reserved floor carried into the decision, bytes.
    pub reserved_bytes: u64,
    /// Bytes granted by the arbiter: the reserved floor plus the
    /// demand top-up from the pooled capacity (= demand when neither the
    /// reservation nor the instance cap binds).
    pub granted_bytes: u64,
    /// Miss-cost weight used for contention ordering.
    pub weight: f64,
}

/// Cost-aware capacity arbiter: Algorithm 2's `ROUND(VC.size / S_p)`
/// generalized to the multi-tenant aggregate, with a Memshare-style
/// reserved/pooled split and weighted trimming when the instance cap
/// binds.
#[derive(Debug, Clone)]
pub struct Arbiter {
    instance_bytes: u64,
    min_instances: u32,
    max_instances: u32,
}

impl Arbiter {
    /// An arbiter for `instance_bytes`-sized nodes under `scaler`'s
    /// min/max instance bounds.
    pub fn new(instance_bytes: u64, scaler: &ScalerConfig) -> Arbiter {
        Arbiter {
            instance_bytes: instance_bytes.max(1),
            min_instances: scaler.min_instances.max(1),
            max_instances: scaler.max_instances.max(1),
        }
    }

    /// Total grantable capacity, bytes.
    pub fn capacity_bytes(&self) -> u64 {
        (self.max_instances as u64).saturating_mul(self.instance_bytes)
    }

    /// Fold per-tenant demand rows into the next cluster size plus the
    /// per-tenant grants. The size is `clamp(round(Σdemand / S_p))`.
    /// Grants are handed out in two phases against the capacity the
    /// instance cap allows: first every tenant's reserved floor (scaled
    /// down proportionally if the floors alone oversubscribe the
    /// cluster), then the pooled remainder in descending miss-cost weight
    /// (ties: bigger demand, then lower tenant id). Σ granted never
    /// exceeds `max_instances × S_p`, and when nothing binds every grant
    /// equals its demand. Under `scaler.enforce_grants` the caller turns
    /// these grants into occupancy caps and TTL clamps
    /// ([`ControllerBank::apply_grant`]); otherwise they are
    /// reporting/diagnostics.
    pub fn decide(&self, demands: &[TenantDemand]) -> (u32, Vec<TenantAllocation>) {
        let total: u64 = demands.iter().map(|d| d.demand_bytes).sum();
        let raw = (total as f64 / self.instance_bytes as f64).round() as u32;
        let n = raw.clamp(self.min_instances, self.max_instances);

        let capacity = self.capacity_bytes();
        let mut allocs: Vec<TenantAllocation> = demands
            .iter()
            .map(|d| TenantAllocation {
                tenant: d.tenant,
                demand_bytes: d.demand_bytes,
                reserved_bytes: d.reserved_bytes,
                granted_bytes: 0,
                weight: d.weight,
            })
            .collect();

        // Phase 1 — reserved floors (Memshare's guaranteed memory),
        // scaled proportionally if the reservations alone oversubscribe
        // the cluster.
        let reserved_sum: u64 = allocs.iter().map(|a| a.reserved_bytes).sum();
        let scale = if reserved_sum > capacity {
            capacity as f64 / reserved_sum as f64
        } else {
            1.0
        };
        let mut remaining = capacity;
        for a in &mut allocs {
            let floor = ((a.reserved_bytes as f64 * scale) as u64).min(remaining);
            a.granted_bytes = floor;
            remaining -= floor;
        }

        // Phase 2 — pooled capacity: top demands up in descending
        // miss-cost weight, so the squeeze lands on the tenants whose
        // misses are cheapest.
        let mut order: Vec<usize> = (0..allocs.len()).collect();
        order.sort_by(|&a, &b| {
            allocs[b]
                .weight
                .partial_cmp(&allocs[a].weight)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(allocs[b].demand_bytes.cmp(&allocs[a].demand_bytes))
                .then(allocs[a].tenant.cmp(&allocs[b].tenant))
        });
        for i in order {
            if allocs[i].demand_bytes > allocs[i].granted_bytes {
                let extra = (allocs[i].demand_bytes - allocs[i].granted_bytes).min(remaining);
                allocs[i].granted_bytes += extra;
                remaining -= extra;
            }
        }
        (n, allocs)
    }
}

/// Multi-tenant version of Algorithm 2: the balancer feeds each request to
/// its tenant's controller; the arbiter sizes the shared cluster from the
/// aggregate shadow demand at each epoch boundary; under
/// `scaler.enforce_grants` the grants feed back as binding occupancy caps
/// and TTL clamps.
pub struct TenantTtlSizer {
    bank: ControllerBank,
    arbiter: Arbiter,
    enforce: bool,
    last_allocations: Vec<TenantAllocation>,
    // Per-stage epoch timers, resolved once by `attach_telemetry`
    // (None = telemetry off: no clock is read).
    arbiter_timer: Option<crate::telemetry::Timer>,
    grant_timer: Option<crate::telemetry::Timer>,
}

impl TenantTtlSizer {
    /// Build from explicit parts (see [`TenantTtlSizer::from_config`]
    /// for the config-driven form).
    pub fn new(
        ctrl: &ControllerConfig,
        cost: CostConfig,
        registry: TenantRegistry,
        instance_bytes: u64,
        scaler: &ScalerConfig,
    ) -> Self {
        TenantTtlSizer {
            bank: ControllerBank::new(ctrl, cost, registry),
            arbiter: Arbiter::new(instance_bytes, scaler),
            enforce: scaler.enforce_grants,
            last_allocations: Vec::new(),
            arbiter_timer: None,
            grant_timer: None,
        }
    }

    /// Build from config; an empty `cfg.tenants` list falls back to the
    /// single default tenant (plus lazy admission of any ids the trace
    /// actually carries).
    pub fn from_config(cfg: &Config) -> Self {
        let registry = if cfg.tenants.is_empty() {
            TenantRegistry::single_tenant()
        } else {
            TenantRegistry::from_specs(cfg.tenants.iter().cloned())
        };
        Self::new(
            &cfg.controller,
            cfg.cost.clone(),
            registry,
            cfg.cost.instance.ram_bytes,
            &cfg.scaler,
        )
    }

    /// The per-tenant controller bank (read-only).
    pub fn bank(&self) -> &ControllerBank {
        &self.bank
    }

    /// Whether grants are binding for this sizer.
    pub fn enforcing(&self) -> bool {
        self.enforce
    }

    /// Per-tenant grants from the most recent epoch decision.
    pub fn allocations(&self) -> &[TenantAllocation] {
        &self.last_allocations
    }
}

impl EpochSizer for TenantTtlSizer {
    fn on_request(&mut self, req: &Request) -> PolicyWork {
        let enforce = self.enforce;
        let slot = self.bank.slot_mut(req.tenant);
        if !slot.life.participates() {
            // A draining/retired tenant is still served (the origin fetch
            // happens either way) but its controller has left the bank:
            // no shadow update, and the miss is never cached — residents
            // only ever shrink while the tenant drains.
            return PolicyWork { units: 2, shadow_hit: None, admit: false };
        }
        slot.life.activate(req.ts);
        let out = slot.vc.on_request(req.ts, req.obj, req.size_bytes());
        // Admission verdict, O(1): objects inside the tenant's virtual
        // (affordable) set always re-admit (repair traffic); everything
        // else must fit the tenant's physical occupancy cap — the insert
        // is admitted only while `resident + size ≤ cap`, where resident
        // is the cluster ledger row the balancer reported via
        // `note_physical`. With enforcement off the verdict is
        // unconditionally yes and no enforcement state is touched.
        let admit = !enforce
            || out.hit
            || slot.cap_bytes == u64::MAX
            || slot.physical_bytes.saturating_add(req.size_bytes()) <= slot.cap_bytes;
        // hash + route (1) + bank dispatch (1) + vcache list ops (≈2):
        // constant, one unit over the single-tenant TTL path; the
        // enforcement compare adds one more constant unit.
        PolicyWork {
            units: 4 + enforce as u32,
            shadow_hit: Some(out.hit),
            admit,
        }
    }

    fn note_physical(&mut self, tenant: TenantId, resident_bytes: u64) {
        if !self.enforce {
            return;
        }
        self.bank.slot_mut(tenant).physical_bytes = resident_bytes;
    }

    fn on_served(&mut self, req: &Request, hit: bool, work: &PolicyWork) {
        self.bank.record_served(
            req.tenant,
            hit,
            work.admit,
            work.shadow_hit == Some(true),
            req.size_bytes(),
        );
    }

    fn decide(&mut self, now: TimeUs) -> u32 {
        self.bank.expire_all(now);
        // Close the SLO measurement windows first so this decision's
        // weights carry the boost earned by the epoch just ending, and
        // count the boundary against any draining tenants (the ≤ K
        // drain bound).
        self.bank.close_epoch_slo();
        self.bank.note_epoch_boundary();
        let demands = self.bank.demands();
        // The arbiter's weight sort is the projected 1000-tenant hotspot
        // (ROADMAP): time it separately from the grant-application loop.
        let (n, allocs) = match self.arbiter_timer.clone() {
            Some(timer) => timer.time(|| self.arbiter.decide(&demands)),
            None => self.arbiter.decide(&demands),
        };
        match self.grant_timer.clone() {
            Some(timer) => timer.time(|| {
                for a in &allocs {
                    self.bank.apply_grant(a, self.enforce);
                }
            }),
            None => {
                for a in &allocs {
                    self.bank.apply_grant(a, self.enforce);
                }
            }
        }
        self.last_allocations = allocs;
        n
    }

    fn name(&self) -> &'static str {
        "tenant_ttl"
    }

    /// Demand-weighted mean of the per-tenant timers (diagnostic series).
    fn ttl_secs(&self) -> Option<f64> {
        let mut wsum = 0.0;
        let mut tsum = 0.0;
        let mut count = 0usize;
        let mut plain = 0.0;
        for (_, vc) in self.bank.iter() {
            let w = vc.vsize() as f64;
            wsum += w;
            tsum += w * vc.ttl_secs();
            plain += vc.ttl_secs();
            count += 1;
        }
        if count == 0 {
            None
        } else if wsum > 0.0 {
            Some(tsum / wsum)
        } else {
            Some(plain / count as f64)
        }
    }

    /// O(1) per-tenant timer for TTL-pricing admission filters — the
    /// tenant's *own* controller, not the `ttl_secs` fleet mean (which
    /// is O(T) and the wrong price for an individual insert).
    fn tenant_ttl_secs(&self, tenant: TenantId) -> Option<f64> {
        self.bank.get(tenant).map(|vc| vc.ttl_secs())
    }

    fn shadow_size(&self) -> Option<u64> {
        Some(self.bank.total_vsize())
    }

    fn tenant_ttls(&self) -> Option<Vec<(TenantId, f64)>> {
        Some(self.bank.ttls())
    }

    fn enforcement(&self) -> Option<Vec<TenantEnforcement>> {
        Some(self.bank.enforcement_rows(self.enforce))
    }

    fn admit_tenant(&mut self, spec: TenantSpec, now: TimeUs) -> crate::Result<AdmitOutcome> {
        self.bank.admit_tenant(spec, now)
    }

    fn retire_tenant(&mut self, tenant: TenantId, now: TimeUs) -> crate::Result<()> {
        self.bank.retire_tenant(tenant, now)
    }

    fn draining(&self) -> Vec<TenantId> {
        self.bank.draining()
    }

    fn note_drained(&mut self, tenant: TenantId, now: TimeUs) {
        self.bank.note_drained(tenant, now);
    }

    fn take_retired(&mut self) -> Vec<TenantId> {
        self.bank.take_retired()
    }

    fn lifecycle(&self) -> Option<Vec<(TenantId, Lifecycle)>> {
        Some(self.bank.lifecycle_rows())
    }

    fn tenant_spec(&self, tenant: TenantId) -> Option<TenantSpec> {
        self.bank.registry().get(tenant).cloned()
    }

    fn attach_telemetry(&mut self, registry: &mut crate::telemetry::TelemetryRegistry) {
        self.arbiter_timer = Some(registry.timer("elastictl_epoch_arbiter_ns"));
        self.grant_timer = Some(registry.timer("elastictl_epoch_grant_apply_ns"));
    }

    fn shard_demands(&mut self, now: TimeUs) -> Option<Vec<TenantDemand>> {
        // Exactly the first half of `decide`: boundary shadow maintenance,
        // then the demand rows the local arbiter would have consumed —
        // reported upward for the front's merged decision instead.
        self.bank.expire_all(now);
        self.bank.close_epoch_slo();
        self.bank.note_epoch_boundary();
        Some(self.bank.demands())
    }

    fn shard_apply_grants(&mut self, allocs: &[TenantAllocation]) {
        // Exactly the second half of `decide`, fed this shard's slice of
        // the front's grants (caps and TTL clamps land per shard).
        for a in allocs {
            self.bank.apply_grant(a, self.enforce);
        }
        self.last_allocations = allocs.to_vec();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::{HOUR, SECOND};

    fn specs_3() -> Vec<TenantSpec> {
        vec![
            TenantSpec::new(0, "api")
                .with_multiplier(3.0)
                .with_class(TrafficClass::Interactive),
            TenantSpec::new(1, "web"),
            TenantSpec::new(2, "batch")
                .with_multiplier(0.3)
                .with_class(TrafficClass::Bulk),
        ]
    }

    #[test]
    fn registry_lookup_and_override() {
        let mut reg = TenantRegistry::from_specs(specs_3());
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.get(0).unwrap().name, "api");
        assert_eq!(reg.multiplier(2), 0.3);
        assert_eq!(reg.multiplier(999), 1.0);
        reg.register(TenantSpec::new(1, "web2").with_multiplier(2.0));
        assert_eq!(reg.len(), 3, "duplicate id must replace, not append");
        assert_eq!(reg.get(1).unwrap().name, "web2");
        assert_eq!(reg.multiplier(1), 2.0);
        assert_eq!(reg.reserved_bytes(1), 0);
        reg.register(TenantSpec::new(4, "gold").with_reserved_bytes(1 << 20));
        assert_eq!(reg.reserved_bytes(4), 1 << 20);
        assert_eq!(reg.reserved_bytes(999), 0);
    }

    #[test]
    fn traffic_class_round_trip() {
        for c in [
            TrafficClass::Interactive,
            TrafficClass::Standard,
            TrafficClass::Bulk,
        ] {
            assert_eq!(TrafficClass::parse(c.as_str()).unwrap(), c);
        }
        assert!(TrafficClass::parse("nope").is_err());
    }

    #[test]
    fn scoped_object_separates_tenants_but_not_tenant_zero() {
        // Tenant 0 is the identity: legacy routing is unchanged.
        for obj in 0..100u64 {
            assert_eq!(scoped_object(0, obj), obj);
        }
        // Distinct tenants map the same key apart, bijectively per tenant.
        let a: std::collections::HashSet<u64> =
            (0..1000u64).map(|o| scoped_object(1, o)).collect();
        assert_eq!(a.len(), 1000);
        let collisions = (0..1000u64)
            .filter(|&o| scoped_object(1, o) == scoped_object(2, o))
            .count();
        assert_eq!(collisions, 0);
    }

    #[test]
    fn bank_dispatches_per_tenant_and_admits_strays() {
        let cfg = Config::default();
        let mut bank = ControllerBank::new(
            &cfg.controller,
            cfg.cost.clone(),
            TenantRegistry::from_specs(specs_3()),
        );
        assert_eq!(bank.len(), 3);
        bank.controller_mut(0).on_request(0, 7, 1000);
        bank.controller_mut(2).on_request(0, 7, 500);
        assert_eq!(bank.get(0).unwrap().vsize(), 1000);
        assert_eq!(bank.get(2).unwrap().vsize(), 500);
        assert_eq!(bank.get(1).unwrap().vsize(), 0);
        // A tenant nobody registered still gets a controller.
        bank.controller_mut(17).on_request(0, 1, 64);
        assert_eq!(bank.len(), 4);
        assert_eq!(bank.get(17).unwrap().vsize(), 64);
        assert_eq!(bank.total_vsize(), 1564);
        bank.expire_all(2 * crate::DAY);
        assert_eq!(bank.total_vsize(), 0);
    }

    #[test]
    fn bank_scales_miss_cost_per_tenant() {
        // The high-multiplier tenant's controller must see a larger miss
        // cost, driving its TTL above the low-multiplier tenant's under
        // the *same* request pattern.
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 30.0;
        let mut bank = ControllerBank::new(
            &cfg.controller,
            cfg.cost.clone(),
            TenantRegistry::from_specs(vec![
                TenantSpec::new(1, "hot").with_multiplier(10.0),
                TenantSpec::new(2, "cold").with_multiplier(0.1),
            ]),
        );
        // Identical traffic into both controllers: each object is
        // requested at cycle start and 20 s later, then left to expire
        // until the next 60 s cycle. Every residency closes a one-hit
        // window, so λ̂ ≈ 1/T and the correction sign is decided by the
        // tenant's miss cost: λ̂·(10·m) ≫ c_100KB > λ̂·(0.1·m).
        let mut events: Vec<(u64, u64)> = Vec::new();
        for k in 0..200u64 {
            for obj in 0..20u64 {
                events.push((k * 60 * SECOND + obj, obj));
                events.push((k * 60 * SECOND + 20 * SECOND + obj, obj));
            }
        }
        events.sort_unstable();
        for (ts, obj) in events {
            bank.controller_mut(1).on_request(ts, obj, 100_000);
            bank.controller_mut(2).on_request(ts, obj, 100_000);
        }
        let t_hot = bank.get(1).unwrap().ttl_secs();
        let t_cold = bank.get(2).unwrap().ttl_secs();
        assert!(
            t_hot > t_cold,
            "expensive-miss tenant should hold longer: hot={t_hot} cold={t_cold}"
        );
        assert!(bank.get(1).unwrap().updates() > 200, "too few updates");
    }

    #[test]
    fn slo_state_escalates_and_decays() {
        let mut s = SloState::new(Some(0.1));
        assert_eq!(s.boost, 1.0);
        // Two violating epochs escalate geometrically…
        for _ in 0..50 {
            s.record(false);
        }
        s.close_epoch();
        assert_eq!(s.measured, Some(1.0));
        assert_eq!(s.boost, 2.0);
        for _ in 0..50 {
            s.record(false);
        }
        s.close_epoch();
        assert_eq!(s.boost, 4.0);
        // …capped at the ceiling…
        for _ in 0..20 {
            for _ in 0..10 {
                s.record(false);
            }
            s.close_epoch();
        }
        assert_eq!(s.boost, SLO_BOOST_MAX);
        // …and a compliant epoch decays it.
        for _ in 0..100 {
            s.record(true);
        }
        s.close_epoch();
        assert_eq!(s.measured, Some(0.0));
        assert_eq!(s.boost, SLO_BOOST_MAX / SLO_BOOST_STEP);
        // Quiet epochs decay too (no escalating on stale data).
        s.close_epoch();
        assert_eq!(s.boost, SLO_BOOST_MAX / SLO_BOOST_STEP / SLO_BOOST_STEP);
        assert_eq!(s.measured, Some(0.0), "measurement persists through quiet epochs");
        // Untracked tenants never budge.
        let mut free = SloState::new(None);
        for _ in 0..10 {
            free.record(false);
        }
        free.close_epoch();
        assert_eq!(free.boost, 1.0);
        assert_eq!(free.measured, Some(1.0));
    }

    #[test]
    fn arbiter_sums_demands_and_clamps() {
        let cfg = Config::default();
        let mut scaler = cfg.scaler.clone();
        scaler.min_instances = 1;
        scaler.max_instances = 4;
        let arb = Arbiter::new(1_000_000, &scaler);
        assert_eq!(arb.capacity_bytes(), 4_000_000);
        // Under the cap: everyone granted in full, size = round(total/S).
        let (n, allocs) = arb.decide(&[
            TenantDemand::new(0, 1_400_000, 3.0),
            TenantDemand::new(1, 700_000, 1.0),
        ]);
        assert_eq!(n, 2);
        assert!(allocs.iter().all(|a| a.granted_bytes == a.demand_bytes));
        // Over the cap: total 9 MB → raw 9 > max 4. High-weight tenant is
        // granted first; the cheap tenant absorbs the squeeze.
        let (n, allocs) = arb.decide(&[
            TenantDemand::new(0, 3_000_000, 3.0),
            TenantDemand::new(1, 6_000_000, 0.3),
        ]);
        assert_eq!(n, 4);
        let a0 = allocs.iter().find(|a| a.tenant == 0).unwrap();
        let a1 = allocs.iter().find(|a| a.tenant == 1).unwrap();
        assert_eq!(a0.granted_bytes, 3_000_000);
        assert_eq!(a1.granted_bytes, 1_000_000);
        // Empty demand set still yields the floor.
        let (n, _) = arb.decide(&[]);
        assert_eq!(n, scaler.min_instances);
    }

    #[test]
    fn arbiter_honors_reserved_floors() {
        let cfg = Config::default();
        let mut scaler = cfg.scaler.clone();
        scaler.min_instances = 1;
        scaler.max_instances = 4;
        let arb = Arbiter::new(1_000_000, &scaler);
        // The cheap tenant's reservation survives the expensive tenant's
        // huge demand: without the floor, weight ordering would hand
        // tenant 0 the whole 4 MB.
        let (_, allocs) = arb.decide(&[
            TenantDemand::new(0, 10_000_000, 5.0),
            TenantDemand::new(1, 2_000_000, 1.0).with_reserved(1_500_000),
        ]);
        let a0 = allocs.iter().find(|a| a.tenant == 0).unwrap();
        let a1 = allocs.iter().find(|a| a.tenant == 1).unwrap();
        assert!(a1.granted_bytes >= 1_500_000, "{a1:?}");
        assert_eq!(a0.granted_bytes + a1.granted_bytes, 4_000_000);
        // A reservation is granted even beyond demand (guaranteed
        // headroom), and oversubscribed reservations scale down
        // proportionally instead of starving anyone.
        let (_, allocs) = arb.decide(&[
            TenantDemand::new(0, 100_000, 1.0).with_reserved(6_000_000),
            TenantDemand::new(1, 100_000, 1.0).with_reserved(2_000_000),
        ]);
        let a0 = allocs.iter().find(|a| a.tenant == 0).unwrap();
        let a1 = allocs.iter().find(|a| a.tenant == 1).unwrap();
        assert!(a0.granted_bytes >= 2_900_000 && a0.granted_bytes <= 3_000_000, "{a0:?}");
        assert!(a1.granted_bytes >= 900_000 && a1.granted_bytes <= 1_000_000, "{a1:?}");
        let total: u64 = allocs.iter().map(|a| a.granted_bytes).sum();
        assert!(total <= arb.capacity_bytes());
    }

    #[test]
    fn tenant_sizer_sizes_shared_cluster_from_aggregate() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0; // sticky ghosts
        cfg.tenants = specs_3();
        let inst = cfg.cost.instance.ram_bytes;
        let mut s = TenantTtlSizer::from_config(&cfg);
        assert_eq!(s.name(), "tenant_ttl");
        assert!(!s.enforcing(), "enforcement is opt-in");
        // ~1 instance worth of ghosts per tenant → aggregate ≈ 3.
        let obj_size = inst / 10;
        for i in 0..10u64 {
            for t in 0..3u16 {
                let req = Request::new(i * SECOND, i, obj_size as u32)
                    .with_tenant(t);
                s.on_request(&req);
            }
        }
        let n = s.decide(20 * SECOND);
        assert_eq!(n, 3, "aggregate demand should need 3 instances");
        assert_eq!(s.allocations().len(), 3);
        assert!(s.shadow_size().unwrap() > 2 * inst);
        let ttls = s.tenant_ttls().unwrap();
        assert_eq!(ttls.len(), 3);
        assert!(s.ttl_secs().is_some());
        // Unenforced: grants recorded but no caps/clamps in force.
        let rows = s.enforcement().unwrap();
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(r.decided);
            assert!(!r.enforced);
            assert_eq!(r.cap_bytes, None);
            assert_eq!(r.ttl_clamp_secs, None);
        }
    }

    #[test]
    fn enforced_sizer_caps_admissions_and_clamps_ttls() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0; // sticky ghosts
        cfg.cost.instance.ram_bytes = 1_000_000;
        cfg.scaler.max_instances = 2; // capacity: 2 MB
        cfg.scaler.enforce_grants = true;
        cfg.tenants = vec![
            TenantSpec::new(0, "gold").with_multiplier(10.0).with_slo_miss_ratio(0.5),
            TenantSpec::new(1, "bulk").with_multiplier(0.5),
        ];
        let mut s = TenantTtlSizer::from_config(&cfg);
        assert!(s.enforcing());
        // Before the first decision nothing is capped: everything admits.
        let w = s.on_request(&Request::new(0, 1, 100_000));
        assert!(w.admit);
        assert_eq!(w.units, 5, "enforcement adds one constant work unit");
        s.on_served(&Request::new(0, 1, 100_000), false, &w);
        // Load both tenants far beyond capacity: gold 1.5 MB, bulk 3 MB.
        for i in 0..15u64 {
            let r = Request::new(i * SECOND, 100 + i, 100_000);
            let w = s.on_request(&r);
            s.on_served(&r, false, &w);
        }
        for i in 0..30u64 {
            let r = Request::new(i * SECOND, 500 + i, 100_000).with_tenant(1);
            let w = s.on_request(&r);
            s.on_served(&r, false, &w);
        }
        let n = s.decide(40 * SECOND);
        assert_eq!(n, 2, "cluster pegged at the cap");
        // Gold (10×) granted in full; bulk squeezed to the remainder and
        // clamped.
        let rows = s.enforcement().unwrap();
        let gold = rows.iter().find(|r| r.tenant == 0).unwrap();
        let bulk = rows.iter().find(|r| r.tenant == 1).unwrap();
        assert!(gold.enforced && bulk.enforced);
        assert_eq!(gold.granted_bytes, gold.demand_bytes, "{gold:?}");
        assert!(bulk.granted_bytes < bulk.demand_bytes, "{bulk:?}");
        assert_eq!(bulk.cap_bytes, Some(bulk.granted_bytes));
        let bulk_cap = bulk.granted_bytes;
        let clamp = bulk.ttl_clamp_secs.expect("squeezed tenant must be clamped");
        assert!(clamp < 3600.0, "clamp {clamp}");
        assert_eq!(gold.ttl_clamp_secs, None, "full grant leaves gold unclamped");
        // The cap binds on *physical residency*: the balancer reports the
        // cluster ledger row via note_physical and fresh inserts admit
        // only while resident + size ≤ cap.
        s.note_physical(1, bulk_cap); // at the cap: fresh insert refused
        let r = Request::new(41 * SECOND, 2000, 100_000).with_tenant(1);
        let w = s.on_request(&r);
        assert!(!w.admit, "insert past the resident cap must be refused");
        s.on_served(&r, false, &w);
        s.note_physical(1, bulk_cap.saturating_sub(200_000)); // room again
        let r = Request::new(41 * SECOND + 1, 2001, 100_000).with_tenant(1);
        assert!(s.on_request(&r).admit, "insert fitting the cap admits");
        // Repair traffic is exempt even over the cap: an object inside
        // bulk's virtual set re-admits regardless of residency.
        s.note_physical(1, bulk_cap + 500_000);
        let r = Request::new(41 * SECOND + 2, 500, 100_000).with_tenant(1);
        let w = s.on_request(&r);
        assert_eq!(w.shadow_hit, Some(true), "precondition: in the shadow set");
        assert!(w.admit, "repair traffic must stay exempt");
        // Gold, resident within its grant, keeps admitting.
        s.note_physical(0, gold.granted_bytes.saturating_sub(100_000));
        let r = Request::new(42 * SECOND, 4242, 100_000);
        assert!(s.on_request(&r).admit, "gold stays within its grant");
        let rows = s.enforcement().unwrap();
        let bulk = rows.iter().find(|r| r.tenant == 1).unwrap();
        assert_eq!(bulk.denied_admissions, 1, "{bulk:?}");
        assert_eq!(bulk.physical_bytes, bulk_cap + 500_000, "ledger mirror");
        // SLO bookkeeping: gold's all-miss warmup epoch violated its 0.5
        // target, so the first decision already escalated its priority.
        let gold = rows.iter().find(|r| r.tenant == 0).unwrap();
        assert_eq!(gold.measured_miss_ratio, Some(1.0));
        assert!(gold.in_violation());
        assert_eq!(gold.boost, SLO_BOOST_STEP);
        // A compliant epoch (all hits on resident ghosts) decays it back.
        for i in 0..10u64 {
            let r = Request::new(50 * SECOND + i, 100 + i, 100_000);
            let w = s.on_request(&r);
            s.on_served(&r, true, &w);
        }
        s.decide(80 * SECOND);
        let rows = s.enforcement().unwrap();
        let gold = rows.iter().find(|r| r.tenant == 0).unwrap();
        assert_eq!(gold.measured_miss_ratio, Some(0.0));
        assert!(!gold.in_violation());
        assert_eq!(gold.boost, 1.0);
    }

    #[test]
    fn lifecycle_states_drive_the_bank() {
        let mut cfg = Config::default();
        cfg.controller.t_init_secs = 3600.0;
        cfg.scaler.policy = crate::config::PolicyKind::TenantTtl;
        cfg.tenants = vec![TenantSpec::new(0, "base")];
        let mut s = TenantTtlSizer::from_config(&cfg);

        // Mid-run admission: the new tenant starts Admitted and
        // activates on its first request.
        let outcome = s.admit_tenant(TenantSpec::new(3, "guest"), 5 * SECOND).unwrap();
        assert_eq!(outcome, AdmitOutcome::Admitted);
        let life = s.lifecycle().unwrap().into_iter().find(|(t, _)| *t == 3).unwrap().1;
        assert_eq!(life.state(), LifecycleState::Admitted);
        assert_eq!(life.admitted_at, 5 * SECOND);
        let w = s.on_request(&Request::new(6 * SECOND, 1, 1000).with_tenant(3));
        assert!(w.admit);
        let life = s.lifecycle().unwrap().into_iter().find(|(t, _)| *t == 3).unwrap().1;
        assert_eq!(life.state(), LifecycleState::Active);
        assert_eq!(life.activated_at, Some(6 * SECOND));
        // Updating a live tenant keeps its state.
        assert_eq!(
            s.admit_tenant(TenantSpec::new(3, "guest").with_slo_miss_ratio(0.2), 7 * SECOND)
                .unwrap(),
            AdmitOutcome::Updated
        );

        // Retirement: demand vanishes, requests are denied admission,
        // and the tenant stops appearing in demands/enforcement.
        assert!(s.shadow_size().unwrap() > 0);
        s.retire_tenant(3, 8 * SECOND).unwrap();
        assert_eq!(s.draining(), vec![3]);
        assert_eq!(s.shadow_size(), Some(0), "controller left the bank");
        let w = s.on_request(&Request::new(9 * SECOND, 2, 1000).with_tenant(3));
        assert!(!w.admit, "draining tenants must not cache");
        assert!(s.enforcement().unwrap().iter().all(|r| r.tenant != 3));
        // Double retire / admit-while-draining are errors.
        assert!(s.retire_tenant(3, 9 * SECOND).is_err());
        assert!(s.admit_tenant(TenantSpec::new(3, "guest"), 9 * SECOND).is_err());
        assert!(s.retire_tenant(99, 9 * SECOND).is_err(), "unknown tenant");

        // A boundary passes, the balancer reports the drain done.
        s.decide(10 * SECOND);
        s.note_drained(3, 10 * SECOND);
        assert_eq!(s.take_retired(), vec![3]);
        assert!(s.take_retired().is_empty(), "queue drains once");
        let life = s.lifecycle().unwrap().into_iter().find(|(t, _)| *t == 3).unwrap().1;
        assert_eq!(life.state(), LifecycleState::Retired);
        assert_eq!(life.drain_epochs, 1);
        assert!(life.drain_epochs <= MAX_DRAIN_EPOCHS);
        assert_eq!(life.retired_at, Some(10 * SECOND));

        // Re-admission starts a fresh lifecycle.
        assert_eq!(
            s.admit_tenant(TenantSpec::new(3, "guest"), 20 * SECOND).unwrap(),
            AdmitOutcome::Readmitted
        );
        let life = s.lifecycle().unwrap().into_iter().find(|(t, _)| *t == 3).unwrap().1;
        assert_eq!(life.state(), LifecycleState::Admitted);
        assert_eq!(life.admitted_at, 20 * SECOND);
        assert_eq!(life.retired_at, None);
    }

    #[test]
    fn single_tenant_fallback_matches_default_registry() {
        let cfg = Config::default();
        let mut s = TenantTtlSizer::from_config(&cfg);
        assert_eq!(s.bank().len(), 1);
        let req = Request::new(0, 1, 1000);
        s.on_request(&req);
        assert_eq!(s.shadow_size(), Some(1000));
        let n = s.decide(HOUR);
        assert_eq!(n, cfg.scaler.min_instances.max(1));
    }
}
