//! `elastictl` — CLI for the elastic cloud-cache coordinator.
//!
//! ```text
//! elastictl gen-trace <out> [--kind akamai|irm|tenants|churn] [--scale smoke|small|full] [--seed N]
//! elastictl run <trace> [--policy fixed|ttl|mrc|ideal_ttl|analytic|tenant_ttl] [--fixed-instances N]
//! elastictl exp <id> [--scale smoke|small|full] [--out DIR]
//!     ids: fig1 fig2 fig4 fig5 fig6 fig7 headline fig8 fig9 fig10 fig11 fig12 fig13 fig14-obs fig15 irm all
//! elastictl plan <trace>
//! elastictl ttlopt <trace>
//! elastictl serve [--addr HOST:PORT] [--policy ...] [--epoch-secs N] [--checkpoint F] [--resume F]
//! elastictl loadgen <trace> [--addr HOST:PORT] [--conns N]
//! Global: --config <file.toml>
//! ```
//!
//! `--kind churn` writes a format-v3 trace whose event lane admits and
//! retires a guest tenant mid-run (as tagged CSV rows when the output
//! path ends in `.csv`); replaying it with `run --policy tenant_ttl`
//! drives the full lifecycle (drain + billing reconciliation). `serve`
//! runs the concurrent durable runtime ([`elastictl::srv`]): wall-clock
//! epochs with `--epoch-secs`, crash-safe billing with
//! `--checkpoint`/`--resume`. `loadgen` replays a trace against a live
//! server over N connections and reports req/s and p50/p99 latency.
//! Argument parsing is hand-rolled (the offline build has no clap).

use elastictl::config::{Config, PolicyKind};
use elastictl::experiments::{self, ExpContext, TraceScale};
use elastictl::trace::{self, FileSource, IrmConfig, IrmGenerator, SynthConfig, SynthGenerator};
use elastictl::Result;
use std::path::PathBuf;

const USAGE: &str = "usage: elastictl [--config FILE] <gen-trace|run|exp|plan|ttlopt|serve|loadgen> [args]
  gen-trace <out> [--kind akamai|irm|tenants|churn] [--scale smoke|small|full] [--seed N]
  run <trace> [--policy fixed|ttl|mrc|ideal_ttl|analytic|tenant_ttl] [--fixed-instances N] [--shards N]
  exp <id> [--scale smoke|small|full] [--out DIR]   (ids: fig1 fig2 fig4 fig5 fig6 fig7 headline fig8 fig9 fig10 fig11 fig12 fig13 fig14-obs fig15 irm ablations all)
  plan <trace>
  ttlopt <trace>
  serve [--addr HOST:PORT] [--policy P] [--epoch-secs N] [--checkpoint FILE] [--resume FILE] [--shards N]
        (protocol: GET [tenant/]key size, STATS [tenant], SLO tenant, PLACEMENT, ADMIT tenant [k=v..], RETIRE tenant, BILL tenant, EPOCH, WHY tenant, METRICS, QUIT — see docs/PROTOCOL.md)
  loadgen <trace> [--addr HOST:PORT] [--conns N]   (replay against a live server, report req/s + p50/p99)";

/// Minimal flag parser: positionals + `--key value` pairs.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { positional, flags })
    }

    fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }
}

/// Parse `--shards N`, with the same bounds `[engine] shards` enforces.
fn parse_shards(s: &str) -> Result<u32> {
    let n: u32 = s.parse()?;
    anyhow::ensure!((1..=256).contains(&n), "--shards must be in 1..=256, got {n}");
    Ok(n)
}

fn parse_scale(s: &str) -> Result<TraceScale> {
    Ok(match s {
        "smoke" => TraceScale::Smoke,
        "small" => TraceScale::Small,
        "full" => TraceScale::Full,
        other => anyhow::bail!("unknown scale {other} (smoke|small|full)"),
    })
}

/// Load a whole trace into memory — only for the offline solvers
/// (`ttlopt`, `plan`) that need random access; `run` streams via
/// [`FileSource`] instead.
fn read_any_trace(path: &PathBuf) -> Result<Vec<trace::Request>> {
    if path.extension().map(|e| e == "csv").unwrap_or(false) {
        trace::read_csv(path)
    } else {
        trace::read_trace(path)
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        println!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv)?;
    let mut cfg = match args.flag("config") {
        Some(p) => Config::from_path(p)?,
        None => Config::default(),
    };
    let cmd = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("{USAGE}"))?
        .as_str();

    match cmd {
        "gen-trace" => {
            let out = PathBuf::from(
                args.positional
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("gen-trace needs an output path"))?,
            );
            let kind = args.flag_or("kind", "akamai");
            let scale = parse_scale(&args.flag_or("scale", "smoke"))?;
            let seed: Option<u64> = args.flag("seed").map(|s| s.parse()).transpose()?;
            // The churn kind writes a v3 trace with the tenant-event lane
            // (mid-run ADMIT/RETIRE); every other kind stays request-only
            // v2.
            if kind == "churn" {
                let reqs = experiments::churn_trace(scale, seed.unwrap_or(0xF16_13));
                let events = experiments::churn_events(cfg.cost.instance.ram_bytes);
                let items = trace::merge_items(reqs, events);
                // A .csv output takes the tagged-row CSV event lane; any
                // other extension writes binary v3.
                let n = if out.extension().map(|e| e == "csv").unwrap_or(false) {
                    trace::write_items_csv(&out, &items)?;
                    items.len() as u64
                } else {
                    trace::write_items(&out, &items)?
                };
                println!("wrote {n} items (requests + tenant events) to {}", out.display());
                return Ok(());
            }
            let reqs = match kind.as_str() {
                "akamai" => {
                    let mut sc: SynthConfig = scale.synth_config();
                    if let Some(s) = seed {
                        sc.seed = s;
                    }
                    SynthGenerator::new(sc).generate()
                }
                "irm" => {
                    let mut ic = IrmConfig::small();
                    if let Some(s) = seed {
                        ic.seed = s;
                    }
                    IrmGenerator::new(ic).generate()
                }
                // The fig10 three-tenant mux (api/web/batch profiles).
                "tenants" => experiments::tenant_trace(scale, seed.unwrap_or(0xF16_10)),
                other => anyhow::bail!("unknown trace kind {other} (akamai|irm|tenants|churn)"),
            };
            let n = trace::write_trace(&out, &reqs)?;
            println!("wrote {n} requests to {}", out.display());
        }
        "run" => {
            let path = PathBuf::from(
                args.positional
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("run needs a trace path"))?,
            );
            cfg.scaler.policy = PolicyKind::parse(&args.flag_or("policy", "ttl"))?;
            if let Some(n) = args.flag("fixed-instances") {
                cfg.scaler.fixed_instances = n.parse()?;
            }
            if let Some(n) = args.flag("shards") {
                cfg.engine.shards = parse_shards(n)?;
            }
            // Stream the trace file through the engine — every policy
            // (analytic included) takes the same entry point, and the
            // trace never materializes in memory.
            let mut src = FileSource::open(&path)?;
            let result = elastictl::engine::run(&cfg, &mut src);
            src.check()?;
            println!(
                "policy={} requests={} miss_ratio={:.4} spurious={} storage=${:.4} miss=${:.4} total=${:.4}",
                result.policy,
                result.requests,
                result.miss_ratio(),
                result.spurious_misses,
                result.storage_cost,
                result.miss_cost,
                result.total_cost
            );
        }
        "exp" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("exp needs an experiment id"))?;
            let scale = parse_scale(&args.flag_or("scale", "smoke"))?;
            let out = PathBuf::from(args.flag_or("out", "results"));
            run_experiment(id, scale, &out)?;
        }
        "plan" => {
            use elastictl::runtime::{artifacts_dir, Planner, PopularityEstimator};
            let path = PathBuf::from(
                args.positional
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("plan needs a trace path"))?,
            );
            let reqs = read_any_trace(&path)?;
            let planner = Planner::load(artifacts_dir(), cfg.controller.t_max_secs);
            let mut est = PopularityEstimator::new();
            for r in &reqs {
                est.record(r.obj, r.size_bytes());
            }
            let end = reqs.last().map(|r| r.ts).unwrap_or(1);
            let stats = est.drain(end, planner.n_buckets(), &cfg.cost);
            let plan = planner.plan(&stats, cfg.cost.instance.ram_bytes)?;
            println!(
                "artifact={} T*={:.1}s cost_rate=${:.3e}/s vsize={:.1}MB instances={}",
                planner.uses_artifact(),
                plan.t_star_secs,
                plan.cost_rate,
                plan.vsize_bytes / 1048576.0,
                plan.instances
            );
        }
        "ttlopt" => {
            let path = PathBuf::from(
                args.positional
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("ttlopt needs a trace path"))?,
            );
            let reqs = read_any_trace(&path)?;
            let res = elastictl::ttlopt::solve(&reqs, &cfg.cost);
            println!(
                "ttl-opt: requests={} miss_ratio={:.4} storage=${:.4} miss=${:.4} total=${:.4} peak={:.1}MB",
                res.requests,
                res.miss_ratio(),
                res.storage_cost,
                res.miss_cost,
                res.total_cost,
                res.peak_bytes as f64 / 1048576.0
            );
        }
        "serve" => {
            cfg.scaler.policy = PolicyKind::parse(&args.flag_or("policy", "ttl"))?;
            let addr = args.flag_or("addr", "127.0.0.1:7171");
            if let Some(n) = args.flag("epoch-secs") {
                cfg.serve.epoch_secs = n.parse()?;
            }
            if let Some(p) = args.flag("checkpoint") {
                cfg.serve.checkpoint_path = Some(p.to_string());
            }
            if let Some(n) = args.flag("shards") {
                cfg.engine.shards = parse_shards(n)?;
            }
            elastictl::srv::serve(cfg, &addr, args.flag("resume"))?;
        }
        "loadgen" => {
            let path = PathBuf::from(
                args.positional
                    .get(1)
                    .ok_or_else(|| anyhow::anyhow!("loadgen needs a trace path"))?,
            );
            let addr = args.flag_or("addr", "127.0.0.1:7171");
            let conns: usize = args.flag_or("conns", "4").parse()?;
            let reqs = read_any_trace(&path)?;
            let report = elastictl::srv::loadgen::run(&addr, &reqs, conns)?;
            println!("{}", report.summary());
        }
        other => anyhow::bail!("unknown command {other}\n{USAGE}"),
    }
    Ok(())
}

fn run_experiment(id: &str, scale: TraceScale, out: &PathBuf) -> Result<()> {
    let ctx = ExpContext::standard(scale, out);
    println!(
        "# trace: {} requests, out: {}",
        ctx.trace.len(),
        ctx.out_dir.display()
    );
    let all = id == "all";
    let mut matched = all;
    if all || id == "fig1" {
        matched = true;
        println!("{}", experiments::run_fig1(&ctx, 500_000)?.render());
    }
    if all || id == "fig2" {
        matched = true;
        let rates = [0.001, 0.003, 0.01, 0.03, 0.1];
        println!("{}", experiments::run_fig2(&ctx, 500_000, &rates)?.render());
    }
    if all || id == "fig4" {
        matched = true;
        println!("{}", experiments::run_fig4(&ctx)?.render());
    }
    if all || id == "fig5" {
        matched = true;
        println!("{}", experiments::run_fig5(&ctx)?.render());
    }
    if all || id == "fig6" || id == "fig7" || id == "headline" {
        matched = true;
        println!("{}", experiments::run_fig6_fig7_headline(&ctx)?.render());
    }
    if all || id == "fig8" {
        matched = true;
        println!("{}", experiments::run_fig8(&ctx)?.render());
    }
    if all || id == "fig9" {
        matched = true;
        println!("{}", experiments::run_fig9(&ctx)?.render());
    }
    if all || id == "fig10" || id == "tenants" {
        matched = true;
        println!("{}", experiments::run_fig10(&ctx, scale)?.render());
    }
    if all || id == "fig11" || id == "slo" {
        matched = true;
        println!("{}", experiments::run_fig11(&ctx, scale)?.render());
    }
    if all || id == "fig12" || id == "placement" {
        matched = true;
        println!("{}", experiments::run_fig12(&ctx, scale)?.render());
    }
    if all || id == "fig13" || id == "churn" {
        matched = true;
        println!("{}", experiments::run_fig13(&ctx, scale)?.render());
    }
    if all || id == "fig14" || id == "fig14-obs" || id == "obs" {
        matched = true;
        println!("{}", experiments::run_fig14_obs(&ctx, scale)?.render());
    }
    if all || id == "fig15" || id == "admission" {
        matched = true;
        // fig15 builds its own scenario zoo (wonder / storm / churn), so
        // only the request volume scales with --scale.
        let n = match scale {
            TraceScale::Smoke => 120_000,
            TraceScale::Small => 600_000,
            TraceScale::Full => 2_000_000,
        };
        println!("{}", experiments::run_fig15(n, &ctx.out_dir)?.render());
    }
    if all || id == "ablations" {
        matched = true;
        println!("{}", experiments::run_epoch_ablation(&ctx)?.render());
        println!("{}", experiments::run_instance_ablation(&ctx)?.render());
        println!("{}", experiments::run_per_content_ablation(&ctx)?.render());
        println!("{}", experiments::run_gain_ablation(&ctx)?.render());
    }
    if all || id == "irm" {
        matched = true;
        let irm = IrmConfig {
            catalogue: 20_000,
            alpha: 0.9,
            total_rate: 400.0,
            duration: 6 * elastictl::HOUR,
            seed: 3,
        };
        println!("{}", experiments::run_irm_convergence(&ctx, &irm)?.render());
    }
    if !matched {
        anyhow::bail!("unknown experiment id {id}\n{USAGE}");
    }
    Ok(())
}
