//! The request record and trace IO.
//!
//! Binary format v2: little-endian fixed 22-byte records
//! `(ts_us: u64, obj: u64, size: u32, tenant: u16)` after a 16-byte header
//! (`b"ELTC"`, version u32, record count u64). Version-1 files (20-byte
//! records without the tenant column) are still readable; their requests
//! load with `tenant = 0`. CSV is also supported for interoperability
//! (`ts_us,obj,size,tenant` with a header line; the legacy three-column
//! header is accepted on read).

use crate::{ObjectId, Result, TenantId, TimeUs};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ELTC";
const VERSION: u32 = 2;
const V1_RECORD_BYTES: usize = 20;
const RECORD_BYTES: usize = 22;

/// One trace record: tenant `tenant` requests `obj` of `size` bytes at
/// time `ts`. Single-workload traces use tenant 0 throughout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub ts: TimeUs,
    pub obj: ObjectId,
    pub size: u32,
    pub tenant: TenantId,
}

impl Request {
    /// A single-tenant (tenant 0) request.
    #[inline]
    pub fn new(ts: TimeUs, obj: ObjectId, size: u32) -> Request {
        Request { ts, obj, size, tenant: 0 }
    }

    /// Same request attributed to `tenant`.
    #[inline]
    pub fn with_tenant(mut self, tenant: TenantId) -> Request {
        self.tenant = tenant;
        self
    }

    #[inline]
    pub fn size_bytes(&self) -> u64 {
        self.size as u64
    }

    #[inline]
    fn encode(&self, buf: &mut [u8; RECORD_BYTES]) {
        buf[0..8].copy_from_slice(&self.ts.to_le_bytes());
        buf[8..16].copy_from_slice(&self.obj.to_le_bytes());
        buf[16..20].copy_from_slice(&self.size.to_le_bytes());
        buf[20..22].copy_from_slice(&self.tenant.to_le_bytes());
    }

    #[inline]
    fn decode(buf: &[u8; RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: u16::from_le_bytes(buf[20..22].try_into().unwrap()),
        }
    }

    #[inline]
    fn decode_v1(buf: &[u8; V1_RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: 0,
        }
    }
}

/// Streaming binary trace writer (always writes the current version).
pub struct TraceWriter {
    out: BufWriter<File>,
    count: u64,
    path: std::path::PathBuf,
}

impl TraceWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u64.to_le_bytes())?; // count patched on finish
        Ok(TraceWriter { out, count: 0, path })
    }

    #[inline]
    pub fn write(&mut self, r: &Request) -> Result<()> {
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        self.out.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Flush and patch the record count into the header.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        let count = self.count;
        drop(self.out);
        // Patch header in place.
        use std::io::{Seek, SeekFrom};
        let mut f = std::fs::OpenOptions::new().write(true).open(&self.path)?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&count.to_le_bytes())?;
        Ok(count)
    }
}

/// Streaming binary trace reader (implements [`super::RequestSource`]).
/// Reads both the current 22-byte records and legacy v1 20-byte records.
pub struct TraceReader {
    input: BufReader<File>,
    remaining: u64,
    version: u32,
}

impl TraceReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut input = BufReader::new(File::open(path.as_ref())?);
        let mut hdr = [0u8; 16];
        input.read_exact(&mut hdr)?;
        anyhow::ensure!(&hdr[0..4] == MAGIC, "not an elastictl trace file");
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "unsupported trace version {version}"
        );
        let remaining = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        Ok(TraceReader { input, remaining, version })
    }

    /// Records left to read.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// On-disk format version (1 = legacy tenant-less records).
    pub fn version(&self) -> u32 {
        self.version
    }
}

impl super::RequestSource for TraceReader {
    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        let req = if self.version == 1 {
            let mut buf = [0u8; V1_RECORD_BYTES];
            match self.input.read_exact(&mut buf) {
                Ok(()) => Request::decode_v1(&buf),
                Err(_) => {
                    self.remaining = 0;
                    return None;
                }
            }
        } else {
            let mut buf = [0u8; RECORD_BYTES];
            match self.input.read_exact(&mut buf) {
                Ok(()) => Request::decode(&buf),
                Err(_) => {
                    self.remaining = 0;
                    return None;
                }
            }
        };
        self.remaining -= 1;
        Some(req)
    }
}

/// Write a whole trace to a binary file. Returns the record count.
pub fn write_trace(path: impl AsRef<Path>, reqs: &[Request]) -> Result<u64> {
    let mut w = TraceWriter::create(path)?;
    for r in reqs {
        w.write(r)?;
    }
    w.finish()
}

/// Read a whole binary trace into memory.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    use super::RequestSource;
    let mut r = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(r.remaining() as usize);
    while let Some(req) = r.next_request() {
        out.push(req);
    }
    Ok(out)
}

/// Write a trace as CSV (`ts_us,obj,size,tenant`).
pub fn write_csv(path: impl AsRef<Path>, reqs: &[Request]) -> Result<()> {
    let mut out = BufWriter::new(File::create(path.as_ref())?);
    writeln!(out, "ts_us,obj,size,tenant")?;
    for r in reqs {
        writeln!(out, "{},{},{},{}", r.ts, r.obj, r.size, r.tenant)?;
    }
    out.flush()?;
    Ok(())
}

/// Read a CSV trace (header line required; the legacy tenant-less header
/// `ts_us,obj,size` is accepted and loads every request as tenant 0).
pub fn read_csv(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    let text = std::fs::read_to_string(path.as_ref())?;
    let mut out = Vec::new();
    let mut has_tenant_column = false;
    for (i, line) in text.lines().enumerate() {
        if i == 0 {
            let hdr = line.trim();
            has_tenant_column = hdr == "ts_us,obj,size,tenant";
            anyhow::ensure!(
                has_tenant_column || hdr == "ts_us,obj,size",
                "unexpected CSV header: {line}"
            );
            continue;
        }
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split(',');
        let ts = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing ts"))?
            .trim()
            .parse()?;
        let obj = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing obj"))?
            .trim()
            .parse()?;
        let size = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing size"))?
            .trim()
            .parse()?;
        let tenant = if has_tenant_column {
            parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {i}: missing tenant"))?
                .trim()
                .parse()?
        } else {
            0
        };
        out.push(Request { ts, obj, size, tenant });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RequestSource;

    fn sample_trace(n: u64) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                ts: i * 1000,
                obj: crate::mix64(i) % 100,
                size: (i % 4096 + 1) as u32,
                tenant: (i % 5) as TenantId,
            })
            .collect()
    }

    #[test]
    fn binary_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        let reqs = sample_trace(1000);
        let n = write_trace(&p, &reqs).unwrap();
        assert_eq!(n, 1000);
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn streaming_reader_counts() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        write_trace(&p, &sample_trace(10)).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.remaining(), 10);
        assert_eq!(r.version(), 2);
        assert_eq!(r.take_requests(4).len(), 4);
        assert_eq!(r.remaining(), 6);
        assert_eq!(r.take_requests(100).len(), 6);
        assert!(r.next_request().is_none());
    }

    #[test]
    fn csv_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = sample_trace(50);
        write_csv(&p, &reqs).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn legacy_csv_header_reads_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("old.csv");
        std::fs::write(&p, "ts_us,obj,size\n5,7,100\n9,8,200\n").unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(
            back,
            vec![Request::new(5, 7, 100), Request::new(9, 8, 200)]
        );
    }

    #[test]
    fn v1_binary_traces_read_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("v1.bin");
        // Hand-build a version-1 file: header + two 20-byte records.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        for (ts, obj, size) in [(11u64, 3u64, 100u32), (22, 4, 200)] {
            bytes.extend_from_slice(&ts.to_le_bytes());
            bytes.extend_from_slice(&obj.to_le_bytes());
            bytes.extend_from_slice(&size.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.version(), 1);
        let back = r.take_requests(10);
        assert_eq!(
            back,
            vec![Request::new(11, 3, 100), Request::new(22, 4, 200)]
        );
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("bad.bin");
        std::fs::write(&p, b"not a trace file at all").unwrap();
        assert!(TraceReader::open(&p).is_err());
    }

    #[test]
    fn encode_decode_identity() {
        let r = Request {
            ts: u64::MAX - 5,
            obj: 0xDEAD_BEEF_CAFE,
            size: u32::MAX,
            tenant: u16::MAX,
        };
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        assert_eq!(Request::decode(&buf), r);
    }
}
