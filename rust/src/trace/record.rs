//! The request record, the tenant-event lane, and trace IO.
//!
//! Binary format v2: little-endian fixed 22-byte records
//! `(ts_us: u64, obj: u64, size: u32, tenant: u16)` after a 16-byte header
//! (`b"ELTC"`, version u32, record count u64). Version-1 files (20-byte
//! records without the tenant column) are still readable; their requests
//! load with `tenant = 0`. CSV is also supported for interoperability
//! (`ts_us,obj,size,tenant` with a header line; the legacy three-column
//! header is accepted on read).
//!
//! Binary format v3 adds the **tenant-event lane**: each record starts
//! with a one-byte tag — `0` = a v2-shaped request record, `1` = a tenant
//! ADMIT event (`ts_us: u64, tenant: u16, reserved_bytes: u64,
//! miss_cost_multiplier: f64, slo_miss_ratio: f64` with NaN encoding
//! "no SLO"), `2` = a tenant RETIRE event (`ts_us: u64, tenant: u16`).
//! The header count counts *items* (requests + events). v3 files are what
//! `gen-trace --kind churn` writes; replaying one through
//! [`crate::engine::run`] admits and retires tenants mid-run exactly as
//! the serve protocol's `ADMIT`/`RETIRE` commands would. v1/v2 files keep
//! reading unchanged, and [`TraceWriter::create`] keeps writing v2 so
//! event-free traces stay byte-identical with earlier releases.

use crate::{ObjectId, Result, TenantId, TimeUs};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ELTC";
const VERSION: u32 = 2;
const EVENT_VERSION: u32 = 3;
const V1_RECORD_BYTES: usize = 20;
const RECORD_BYTES: usize = 22;
/// v3 record tags.
const TAG_REQUEST: u8 = 0;
const TAG_ADMIT: u8 = 1;
const TAG_RETIRE: u8 = 2;
/// v3 ADMIT payload: ts u64 + tenant u16 + reserved u64 + multiplier f64
/// + slo f64.
const ADMIT_BYTES: usize = 8 + 2 + 8 + 8 + 8;
/// v3 RETIRE payload: ts u64 + tenant u16.
const RETIRE_BYTES: usize = 8 + 2;

/// One trace record: tenant `tenant` requests `obj` of `size` bytes at
/// time `ts`. Single-workload traces use tenant 0 throughout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub ts: TimeUs,
    pub obj: ObjectId,
    pub size: u32,
    pub tenant: TenantId,
}

impl Request {
    /// A single-tenant (tenant 0) request.
    #[inline]
    pub fn new(ts: TimeUs, obj: ObjectId, size: u32) -> Request {
        Request { ts, obj, size, tenant: 0 }
    }

    /// Same request attributed to `tenant`.
    #[inline]
    pub fn with_tenant(mut self, tenant: TenantId) -> Request {
        self.tenant = tenant;
        self
    }

    #[inline]
    pub fn size_bytes(&self) -> u64 {
        self.size as u64
    }

    #[inline]
    fn encode(&self, buf: &mut [u8; RECORD_BYTES]) {
        buf[0..8].copy_from_slice(&self.ts.to_le_bytes());
        buf[8..16].copy_from_slice(&self.obj.to_le_bytes());
        buf[16..20].copy_from_slice(&self.size.to_le_bytes());
        buf[20..22].copy_from_slice(&self.tenant.to_le_bytes());
    }

    #[inline]
    fn decode(buf: &[u8; RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: u16::from_le_bytes(buf[20..22].try_into().unwrap()),
        }
    }

    #[inline]
    fn decode_v1(buf: &[u8; V1_RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: 0,
        }
    }
}

/// What a tenant lifecycle event does when it is replayed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TenantEventKind {
    /// Admit the tenant into the provisioning layer (controller bank,
    /// arbiter, placement) with the carried spec fields.
    Admit {
        /// Memshare-style byte reservation (`reserved_mb` on the wire).
        reserved_bytes: u64,
        /// Miss-cost multiplier applied to the catalog per-miss cost.
        miss_cost_multiplier: f64,
        /// Optional miss-ratio SLO target.
        slo_miss_ratio: Option<f64>,
    },
    /// Begin retiring the tenant: drain its residents and reconcile its
    /// bill (the serve protocol's `RETIRE`).
    Retire,
}

/// One tenant lifecycle event in the trace's event lane (format v3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantEvent {
    /// Trace time at which the event fires.
    pub ts: TimeUs,
    /// The tenant admitted or retired.
    pub tenant: TenantId,
    /// What happens.
    pub kind: TenantEventKind,
}

impl TenantEvent {
    /// An ADMIT event with default spec fields (no reservation, 1× miss
    /// cost, no SLO).
    pub fn admit(ts: TimeUs, tenant: TenantId) -> TenantEvent {
        TenantEvent {
            ts,
            tenant,
            kind: TenantEventKind::Admit {
                reserved_bytes: 0,
                miss_cost_multiplier: 1.0,
                slo_miss_ratio: None,
            },
        }
    }

    /// A RETIRE event.
    pub fn retire(ts: TimeUs, tenant: TenantId) -> TenantEvent {
        TenantEvent { ts, tenant, kind: TenantEventKind::Retire }
    }

    /// Set the admit reservation (no-op on a retire event).
    pub fn with_reserved_bytes(mut self, bytes: u64) -> TenantEvent {
        if let TenantEventKind::Admit { reserved_bytes, .. } = &mut self.kind {
            *reserved_bytes = bytes;
        }
        self
    }

    /// Set the admit miss-cost multiplier (no-op on a retire event).
    pub fn with_multiplier(mut self, m: f64) -> TenantEvent {
        if let TenantEventKind::Admit { miss_cost_multiplier, .. } = &mut self.kind {
            *miss_cost_multiplier = m;
        }
        self
    }

    /// Set the admit SLO target (no-op on a retire event).
    pub fn with_slo_miss_ratio(mut self, target: f64) -> TenantEvent {
        if let TenantEventKind::Admit { slo_miss_ratio, .. } = &mut self.kind {
            *slo_miss_ratio = Some(target);
        }
        self
    }

    /// The [`crate::tenant::TenantSpec`] an admit event carries (`None`
    /// for retire events).
    pub fn spec(&self) -> Option<crate::tenant::TenantSpec> {
        match self.kind {
            TenantEventKind::Admit {
                reserved_bytes,
                miss_cost_multiplier,
                slo_miss_ratio,
            } => {
                let mut spec =
                    crate::tenant::TenantSpec::new(self.tenant, format!("tenant{}", self.tenant))
                        .with_multiplier(miss_cost_multiplier)
                        .with_reserved_bytes(reserved_bytes);
                if let Some(slo) = slo_miss_ratio {
                    spec = spec.with_slo_miss_ratio(slo);
                }
                Some(spec)
            }
            TenantEventKind::Retire => None,
        }
    }
}

/// One item of a trace stream: a request, or a tenant lifecycle event
/// interleaved with the requests (format v3's event lane).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceItem {
    /// An ordinary cache request.
    Request(Request),
    /// A tenant admit/retire event.
    Event(TenantEvent),
}

impl TraceItem {
    /// Timestamp of the item (request or event).
    pub fn ts(&self) -> TimeUs {
        match self {
            TraceItem::Request(r) => r.ts,
            TraceItem::Event(e) => e.ts,
        }
    }
}

/// Streaming binary trace writer. [`TraceWriter::create`] writes format
/// v2 (requests only, byte-identical with earlier releases);
/// [`TraceWriter::create_with_events`] writes format v3 with the tagged
/// tenant-event lane.
pub struct TraceWriter {
    out: BufWriter<File>,
    count: u64,
    version: u32,
    path: std::path::PathBuf,
}

impl TraceWriter {
    /// Create a v2 (request-only) trace file.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        Self::create_version(path, VERSION)
    }

    /// Create a v3 trace file whose record stream may interleave
    /// [`TenantEvent`]s with requests.
    pub fn create_with_events(path: impl AsRef<Path>) -> Result<Self> {
        Self::create_version(path, EVENT_VERSION)
    }

    fn create_version(path: impl AsRef<Path>, version: u32) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        out.write_all(MAGIC)?;
        out.write_all(&version.to_le_bytes())?;
        out.write_all(&0u64.to_le_bytes())?; // count patched on finish
        Ok(TraceWriter { out, count: 0, version, path })
    }

    /// Append one request record.
    #[inline]
    pub fn write(&mut self, r: &Request) -> Result<()> {
        if self.version >= EVENT_VERSION {
            self.out.write_all(&[TAG_REQUEST])?;
        }
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        self.out.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Append one tenant lifecycle event (v3 files only; a v2 writer has
    /// no event lane and errors).
    pub fn write_event(&mut self, ev: &TenantEvent) -> Result<()> {
        anyhow::ensure!(
            self.version >= EVENT_VERSION,
            "trace format v{} has no tenant-event lane (use TraceWriter::create_with_events)",
            self.version
        );
        match ev.kind {
            TenantEventKind::Admit {
                reserved_bytes,
                miss_cost_multiplier,
                slo_miss_ratio,
            } => {
                let mut buf = [0u8; 1 + ADMIT_BYTES];
                buf[0] = TAG_ADMIT;
                buf[1..9].copy_from_slice(&ev.ts.to_le_bytes());
                buf[9..11].copy_from_slice(&ev.tenant.to_le_bytes());
                buf[11..19].copy_from_slice(&reserved_bytes.to_le_bytes());
                buf[19..27].copy_from_slice(&miss_cost_multiplier.to_le_bytes());
                buf[27..35].copy_from_slice(&slo_miss_ratio.unwrap_or(f64::NAN).to_le_bytes());
                self.out.write_all(&buf)?;
            }
            TenantEventKind::Retire => {
                let mut buf = [0u8; 1 + RETIRE_BYTES];
                buf[0] = TAG_RETIRE;
                buf[1..9].copy_from_slice(&ev.ts.to_le_bytes());
                buf[9..11].copy_from_slice(&ev.tenant.to_le_bytes());
                self.out.write_all(&buf)?;
            }
        }
        self.count += 1;
        Ok(())
    }

    /// Append one trace item (request or event).
    pub fn write_item(&mut self, item: &TraceItem) -> Result<()> {
        match item {
            TraceItem::Request(r) => self.write(r),
            TraceItem::Event(e) => self.write_event(e),
        }
    }

    /// Flush and patch the record count into the header.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        let count = self.count;
        drop(self.out);
        // Patch header in place.
        use std::io::{Seek, SeekFrom};
        let mut f = std::fs::OpenOptions::new().write(true).open(&self.path)?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&count.to_le_bytes())?;
        Ok(count)
    }
}

/// Streaming binary trace reader (implements [`super::RequestSource`]).
/// Reads the v3 tagged records (requests + tenant events), the v2
/// 22-byte records, and legacy v1 20-byte records. On a v3 file,
/// [`super::RequestSource::next_request`] silently skips the event lane
/// (request-only consumers keep working); event-aware consumers drive
/// [`super::RequestSource::next_item`] instead. A short read (truncated
/// file, header count larger than the records present) ends the stream;
/// [`TraceReader::check`] surfaces it after the drive loop (the
/// `RequestSource` contract has no error channel).
pub struct TraceReader {
    input: BufReader<File>,
    remaining: u64,
    version: u32,
    error: Option<anyhow::Error>,
}

impl TraceReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut input = BufReader::new(File::open(path.as_ref())?);
        let mut hdr = [0u8; 16];
        input.read_exact(&mut hdr)?;
        anyhow::ensure!(&hdr[0..4] == MAGIC, "not an elastictl trace file");
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == 1 || version == VERSION || version == EVENT_VERSION,
            "unsupported trace version {version}"
        );
        let remaining = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        Ok(TraceReader { input, remaining, version, error: None })
    }

    /// Items left to read (requests + events).
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// On-disk format version (1 = legacy tenant-less records, 3 = the
    /// tagged request + tenant-event stream).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Whether the file carries the v3 tenant-event lane.
    pub fn has_events(&self) -> bool {
        self.version >= EVENT_VERSION
    }

    /// Surface (and clear) any IO error that ended the stream early.
    pub fn check(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn fail(&mut self, e: std::io::Error) {
        self.error = Some(anyhow::Error::new(e).context(format!(
            "trace truncated with {} records still expected",
            self.remaining
        )));
        self.remaining = 0;
    }

    fn fail_tag(&mut self, tag: u8) {
        self.error = Some(anyhow::anyhow!(
            "corrupt v3 trace: unknown record tag {tag} with {} records still expected",
            self.remaining
        ));
        self.remaining = 0;
    }

    /// Read one fixed-size payload, or end the stream on a short read.
    fn read_payload<const N: usize>(&mut self) -> Option<[u8; N]> {
        let mut buf = [0u8; N];
        match self.input.read_exact(&mut buf) {
            Ok(()) => Some(buf),
            Err(e) => {
                self.fail(e);
                None
            }
        }
    }

    /// Read the next v3 tagged item.
    fn read_item_v3(&mut self) -> Option<TraceItem> {
        let tag = self.read_payload::<1>()?[0];
        match tag {
            TAG_REQUEST => {
                let buf = self.read_payload::<RECORD_BYTES>()?;
                Some(TraceItem::Request(Request::decode(&buf)))
            }
            TAG_ADMIT => {
                let buf = self.read_payload::<ADMIT_BYTES>()?;
                let slo = f64::from_le_bytes(buf[26..34].try_into().unwrap());
                Some(TraceItem::Event(TenantEvent {
                    ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
                    tenant: u16::from_le_bytes(buf[8..10].try_into().unwrap()),
                    kind: TenantEventKind::Admit {
                        reserved_bytes: u64::from_le_bytes(buf[10..18].try_into().unwrap()),
                        miss_cost_multiplier: f64::from_le_bytes(buf[18..26].try_into().unwrap()),
                        slo_miss_ratio: if slo.is_nan() { None } else { Some(slo) },
                    },
                }))
            }
            TAG_RETIRE => {
                let buf = self.read_payload::<RETIRE_BYTES>()?;
                Some(TraceItem::Event(TenantEvent::retire(
                    u64::from_le_bytes(buf[0..8].try_into().unwrap()),
                    u16::from_le_bytes(buf[8..10].try_into().unwrap()),
                )))
            }
            other => {
                self.fail_tag(other);
                None
            }
        }
    }
}

impl super::RequestSource for TraceReader {
    fn next_request(&mut self) -> Option<Request> {
        // Request-only consumers of a v3 file skip the event lane.
        loop {
            match super::RequestSource::next_item(self)? {
                TraceItem::Request(r) => return Some(r),
                TraceItem::Event(_) => continue,
            }
        }
    }

    fn next_item(&mut self) -> Option<TraceItem> {
        if self.remaining == 0 {
            return None;
        }
        let item = match self.version {
            1 => {
                let buf = self.read_payload::<V1_RECORD_BYTES>()?;
                TraceItem::Request(Request::decode_v1(&buf))
            }
            VERSION => {
                let buf = self.read_payload::<RECORD_BYTES>()?;
                TraceItem::Request(Request::decode(&buf))
            }
            _ => self.read_item_v3()?,
        };
        self.remaining -= 1;
        Some(item)
    }
}

/// Write a whole trace to a binary file. Returns the record count.
pub fn write_trace(path: impl AsRef<Path>, reqs: &[Request]) -> Result<u64> {
    let mut w = TraceWriter::create(path)?;
    for r in reqs {
        w.write(r)?;
    }
    w.finish()
}

/// Write a whole item stream (requests + tenant events) as a v3 binary
/// trace. Returns the item count.
pub fn write_items(path: impl AsRef<Path>, items: &[TraceItem]) -> Result<u64> {
    let mut w = TraceWriter::create_with_events(path)?;
    for item in items {
        w.write_item(item)?;
    }
    w.finish()
}

/// Read a whole binary trace (any version) into memory as items.
pub fn read_items(path: impl AsRef<Path>) -> Result<Vec<TraceItem>> {
    use super::RequestSource;
    let mut r = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(r.remaining() as usize);
    while let Some(item) = r.next_item() {
        out.push(item);
    }
    r.check()?;
    Ok(out)
}

/// Read a whole binary trace into memory.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    use super::RequestSource;
    let mut r = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(r.remaining() as usize);
    while let Some(req) = r.next_request() {
        out.push(req);
    }
    Ok(out)
}

/// Write a trace as CSV (`ts_us,obj,size,tenant`).
pub fn write_csv(path: impl AsRef<Path>, reqs: &[Request]) -> Result<()> {
    let mut out = BufWriter::new(File::create(path.as_ref())?);
    writeln!(out, "ts_us,obj,size,tenant")?;
    for r in reqs {
        writeln!(out, "{},{},{},{}", r.ts, r.obj, r.size, r.tenant)?;
    }
    out.flush()?;
    Ok(())
}

/// Write an item stream (requests + tenant events) as CSV — the textual
/// face of the v3 event lane. Request rows keep the plain
/// `ts_us,obj,size,tenant` dialect; events ride tagged rows
/// (`ADMIT,<ts>,<tenant>,<reserved_bytes>,<multiplier>,<slo|->` and
/// `RETIRE,<ts>,<tenant>`), so request-only consumers of the same file
/// skip them exactly as [`TraceReader`] skips the binary event lane.
/// Floats print in shortest-round-trip form, so a read-back is
/// bit-identical.
pub fn write_items_csv(path: impl AsRef<Path>, items: &[TraceItem]) -> Result<()> {
    let mut out = BufWriter::new(File::create(path.as_ref())?);
    writeln!(out, "ts_us,obj,size,tenant")?;
    for item in items {
        match item {
            TraceItem::Request(r) => {
                writeln!(out, "{},{},{},{}", r.ts, r.obj, r.size, r.tenant)?
            }
            TraceItem::Event(e) => match e.kind {
                TenantEventKind::Admit {
                    reserved_bytes,
                    miss_cost_multiplier,
                    slo_miss_ratio,
                } => {
                    let slo =
                        slo_miss_ratio.map(|s| s.to_string()).unwrap_or_else(|| "-".to_string());
                    writeln!(
                        out,
                        "ADMIT,{},{},{},{},{}",
                        e.ts, e.tenant, reserved_bytes, miss_cost_multiplier, slo
                    )?
                }
                TenantEventKind::Retire => writeln!(out, "RETIRE,{},{}", e.ts, e.tenant)?,
            },
        }
    }
    out.flush()?;
    Ok(())
}

/// Streaming CSV trace reader (implements [`super::RequestSource`]): same
/// dialect as [`read_csv`] — header line required, the legacy tenant-less
/// `ts_us,obj,size` header accepted (tenant 0), blank lines skipped — in
/// constant memory. Tagged `ADMIT,...`/`RETIRE,...` rows (the
/// [`write_items_csv`] event lane) surface through `next_item` and are
/// skipped by `next_request`, mirroring [`TraceReader`] on a v3 file. A
/// malformed line or a mid-stream IO error ends the stream;
/// [`CsvReader::check`] surfaces it after the drive loop (the
/// `RequestSource` contract has no error channel).
pub struct CsvReader {
    lines: std::io::Lines<BufReader<File>>,
    has_tenant_column: bool,
    /// 1-based data-line counter (the header is line 0), for error reports.
    lineno: usize,
    error: Option<anyhow::Error>,
}

impl CsvReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut lines = BufReader::new(File::open(path.as_ref())?).lines();
        // An empty file is an empty trace (matching the pre-streaming
        // reader); a present header must be one of the two known shapes.
        let has_tenant_column = match lines.next().transpose()? {
            None => false,
            Some(header) => {
                let hdr = header.trim();
                let tenant = hdr == "ts_us,obj,size,tenant";
                anyhow::ensure!(
                    tenant || hdr == "ts_us,obj,size",
                    "unexpected CSV header: {header}"
                );
                tenant
            }
        };
        Ok(CsvReader { lines, has_tenant_column, lineno: 0, error: None })
    }

    /// Whether the file carries the v2 tenant column.
    pub fn has_tenant_column(&self) -> bool {
        self.has_tenant_column
    }

    /// Surface (and clear) any error that ended the stream early.
    pub fn check(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn parse_line(&self, line: &str) -> Result<Request> {
        let i = self.lineno;
        let mut parts = line.split(',');
        let ts = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing ts"))?
            .trim()
            .parse()?;
        let obj = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing obj"))?
            .trim()
            .parse()?;
        let size = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing size"))?
            .trim()
            .parse()?;
        let tenant = if self.has_tenant_column {
            parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {i}: missing tenant"))?
                .trim()
                .parse()?
        } else {
            0
        };
        Ok(Request { ts, obj, size, tenant })
    }

    /// Whether a data row is a tagged tenant-event row rather than a
    /// request row (request rows start with a numeric timestamp).
    fn is_event_line(line: &str) -> bool {
        line.starts_with("ADMIT,") || line.starts_with("RETIRE,")
    }

    fn parse_event(&self, line: &str) -> Result<TenantEvent> {
        let i = self.lineno;
        let mut parts = line.split(',');
        let tag = parts.next().unwrap_or_default();
        let mut field = |name: &str| {
            parts
                .next()
                .map(str::trim)
                .ok_or_else(|| anyhow::anyhow!("line {i}: missing {name}"))
        };
        let ts: TimeUs = field("ts")?.parse()?;
        let tenant: TenantId = field("tenant")?.parse()?;
        match tag {
            "RETIRE" => Ok(TenantEvent::retire(ts, tenant)),
            "ADMIT" => {
                let reserved: u64 = field("reserved_bytes")?.parse()?;
                let multiplier: f64 = field("multiplier")?.parse()?;
                let slo = field("slo")?;
                let mut ev = TenantEvent::admit(ts, tenant)
                    .with_reserved_bytes(reserved)
                    .with_multiplier(multiplier);
                if slo != "-" {
                    ev = ev.with_slo_miss_ratio(slo.parse()?);
                }
                Ok(ev)
            }
            other => anyhow::bail!("line {i}: unknown event tag {other}"),
        }
    }
}

impl super::RequestSource for CsvReader {
    fn next_request(&mut self) -> Option<Request> {
        // Request-only consumers skip the event lane, exactly as
        // `TraceReader::next_request` does on a v3 binary file.
        loop {
            match super::RequestSource::next_item(self)? {
                TraceItem::Request(r) => return Some(r),
                TraceItem::Event(_) => continue,
            }
        }
    }

    fn next_item(&mut self) -> Option<TraceItem> {
        if self.error.is_some() {
            return None;
        }
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => {
                    self.error = Some(e.into());
                    return None;
                }
            };
            self.lineno += 1;
            let data = line.trim();
            if data.is_empty() {
                continue;
            }
            let item = if Self::is_event_line(data) {
                self.parse_event(data).map(TraceItem::Event)
            } else {
                self.parse_line(&line).map(TraceItem::Request)
            };
            match item {
                Ok(it) => return Some(it),
                Err(e) => {
                    self.error = Some(e);
                    return None;
                }
            }
        }
    }
}

/// Read a CSV trace into memory (header line required; the legacy
/// tenant-less header `ts_us,obj,size` is accepted and loads every
/// request as tenant 0). Streaming callers use [`CsvReader`] directly.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    use super::RequestSource;
    let mut r = CsvReader::open(path)?;
    let mut out = Vec::new();
    while let Some(req) = r.next_request() {
        out.push(req);
    }
    r.check()?;
    Ok(out)
}

/// Read a CSV trace into memory as items, tagged tenant-event rows
/// included (the inverse of [`write_items_csv`]).
pub fn read_items_csv(path: impl AsRef<Path>) -> Result<Vec<TraceItem>> {
    use super::RequestSource;
    let mut r = CsvReader::open(path)?;
    let mut out = Vec::new();
    while let Some(item) = r.next_item() {
        out.push(item);
    }
    r.check()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RequestSource;

    fn sample_trace(n: u64) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                ts: i * 1000,
                obj: crate::mix64(i) % 100,
                size: (i % 4096 + 1) as u32,
                tenant: (i % 5) as TenantId,
            })
            .collect()
    }

    #[test]
    fn binary_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        let reqs = sample_trace(1000);
        let n = write_trace(&p, &reqs).unwrap();
        assert_eq!(n, 1000);
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn streaming_reader_counts() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        write_trace(&p, &sample_trace(10)).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.remaining(), 10);
        assert_eq!(r.version(), 2);
        assert_eq!(r.take_requests(4).len(), 4);
        assert_eq!(r.remaining(), 6);
        assert_eq!(r.take_requests(100).len(), 6);
        assert!(r.next_request().is_none());
    }

    #[test]
    fn csv_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = sample_trace(50);
        write_csv(&p, &reqs).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn legacy_csv_header_reads_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("old.csv");
        std::fs::write(&p, "ts_us,obj,size\n5,7,100\n9,8,200\n").unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(
            back,
            vec![Request::new(5, 7, 100), Request::new(9, 8, 200)]
        );
    }

    #[test]
    fn v1_binary_traces_read_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("v1.bin");
        // Hand-build a version-1 file: header + two 20-byte records.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        for (ts, obj, size) in [(11u64, 3u64, 100u32), (22, 4, 200)] {
            bytes.extend_from_slice(&ts.to_le_bytes());
            bytes.extend_from_slice(&obj.to_le_bytes());
            bytes.extend_from_slice(&size.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.version(), 1);
        let back = r.take_requests(10);
        assert_eq!(
            back,
            vec![Request::new(11, 3, 100), Request::new(22, 4, 200)]
        );
    }

    #[test]
    fn truncated_binary_trace_surfaces_an_error() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        write_trace(&p, &sample_trace(10)).unwrap();
        // Chop the file mid-record: 16-byte header + 3 full records + 5
        // stray bytes, while the header still promises 10 records.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..16 + 3 * RECORD_BYTES + 5]).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        let got = r.take_requests(100);
        assert_eq!(got.len(), 3, "stream must stop at the torn record");
        let err = r.check().expect_err("truncation must be reported");
        assert!(err.to_string().contains("truncated"), "{err}");
        // check() clears the error once reported.
        r.check().unwrap();
    }

    #[test]
    fn csv_reader_streams_and_surfaces_errors() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = sample_trace(100);
        write_csv(&p, &reqs).unwrap();
        let mut r = CsvReader::open(&p).unwrap();
        assert!(r.has_tenant_column());
        let mut back = Vec::new();
        while let Some(req) = r.next_request() {
            back.push(req);
        }
        r.check().unwrap();
        assert_eq!(back, reqs);

        // A malformed line ends the stream and check() reports it.
        let bad = dir.path().join("bad.csv");
        std::fs::write(&bad, "ts_us,obj,size\n1,2,100\nnot,a,number\n9,9,9\n").unwrap();
        let mut r = CsvReader::open(&bad).unwrap();
        assert!(r.next_request().is_some());
        assert!(r.next_request().is_none(), "stream must stop at the bad line");
        assert!(r.check().is_err());
        // check() clears the error once reported.
        assert!(r.check().is_ok());
        // …and the batch reader propagates the same failure.
        assert!(read_csv(&bad).is_err());

        // An empty file is an empty trace, not a header error.
        let empty = dir.path().join("empty.csv");
        std::fs::write(&empty, "").unwrap();
        let mut r = CsvReader::open(&empty).unwrap();
        assert!(r.next_request().is_none());
        r.check().unwrap();

        // A wrong header is rejected at open.
        let hdr = dir.path().join("hdr.csv");
        std::fs::write(&hdr, "a,b,c\n1,2,3\n").unwrap();
        assert!(CsvReader::open(&hdr).is_err());
    }

    #[test]
    fn csv_event_lane_round_trips_and_request_readers_skip_it() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("churn.csv");
        let items = vec![
            TraceItem::Event(
                TenantEvent::admit(0, 3)
                    .with_reserved_bytes(1 << 20)
                    .with_multiplier(4.5)
                    .with_slo_miss_ratio(0.1),
            ),
            TraceItem::Request(Request::new(5, 7, 100).with_tenant(3)),
            TraceItem::Event(TenantEvent::admit(6, 4)), // defaults, no SLO
            TraceItem::Request(Request::new(9, 8, 200)),
            TraceItem::Event(TenantEvent::retire(20, 3)),
        ];
        write_items_csv(&p, &items).unwrap();
        assert_eq!(read_items_csv(&p).unwrap(), items);
        // Request-only consumers (read_csv / next_request) skip events.
        assert_eq!(
            read_csv(&p).unwrap(),
            vec![
                Request::new(5, 7, 100).with_tenant(3),
                Request::new(9, 8, 200),
            ]
        );
        // FileSource picks the CSV lane by extension and streams items.
        let mut src = super::super::FileSource::open(&p).unwrap();
        let mut back = Vec::new();
        while let Some(item) = src.next_item() {
            back.push(item);
        }
        src.check().unwrap();
        assert_eq!(back, items);

        // Malformed event rows end the stream and check() reports them.
        for bad_row in ["ADMIT,1,2,3,4", "RETIRE,1", "ADMIT,1,2,nope,1.0,-"] {
            let bad = dir.path().join("bad.csv");
            std::fs::write(&bad, format!("ts_us,obj,size,tenant\n{bad_row}\n")).unwrap();
            assert!(read_items_csv(&bad).is_err(), "{bad_row} must fail");
        }
    }

    #[test]
    fn v3_items_round_trip_and_v2_readers_skip_events() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("churn.bin");
        let items = vec![
            TraceItem::Event(
                TenantEvent::admit(0, 3)
                    .with_reserved_bytes(1 << 20)
                    .with_multiplier(4.0)
                    .with_slo_miss_ratio(0.1),
            ),
            TraceItem::Request(Request::new(5, 7, 100).with_tenant(3)),
            TraceItem::Request(Request::new(9, 8, 200)),
            TraceItem::Event(TenantEvent::retire(20, 3)),
        ];
        let n = write_items(&p, &items).unwrap();
        assert_eq!(n, 4);
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.version(), 3);
        assert!(r.has_events());
        assert_eq!(r.remaining(), 4);
        let back = read_items(&p).unwrap();
        assert_eq!(back, items);
        // A request-only consumer sees just the requests, in order.
        let reqs = read_trace(&p).unwrap();
        assert_eq!(
            reqs,
            vec![
                Request::new(5, 7, 100).with_tenant(3),
                Request::new(9, 8, 200),
            ]
        );
        // The admit spec materializes; the retire carries none.
        let spec = match items[0] {
            TraceItem::Event(e) => e.spec().unwrap(),
            _ => unreachable!(),
        };
        assert_eq!(spec.id, 3);
        assert_eq!(spec.reserved_bytes, 1 << 20);
        assert_eq!(spec.miss_cost_multiplier, 4.0);
        assert_eq!(spec.slo_miss_ratio, Some(0.1));
        match items[3] {
            TraceItem::Event(e) => assert!(e.spec().is_none()),
            _ => unreachable!(),
        }
        // A v2 writer refuses the event lane.
        let mut w = TraceWriter::create(dir.path().join("v2.bin")).unwrap();
        assert!(w.write_event(&TenantEvent::retire(0, 1)).is_err());
    }

    #[test]
    fn v3_truncation_and_bad_tags_surface_errors() {
        use crate::trace::RequestSource;
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("churn.bin");
        let items = vec![
            TraceItem::Request(Request::new(1, 1, 10)),
            TraceItem::Event(TenantEvent::admit(2, 1)),
        ];
        write_items(&p, &items).unwrap();
        // Chop mid-event: header + request record (tagged) + 3 bytes.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..16 + 1 + RECORD_BYTES + 3]).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert!(matches!(r.next_item(), Some(TraceItem::Request(_))));
        assert!(r.next_item().is_none());
        assert!(r.check().is_err());
        // An unknown tag is corruption, not silence.
        let mut bad = bytes.clone();
        bad[16] = 9;
        std::fs::write(&p, &bad).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert!(r.next_item().is_none());
        let err = r.check().expect_err("bad tag must be reported");
        assert!(err.to_string().contains("tag"), "{err}");
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("bad.bin");
        std::fs::write(&p, b"not a trace file at all").unwrap();
        assert!(TraceReader::open(&p).is_err());
    }

    #[test]
    fn encode_decode_identity() {
        let r = Request {
            ts: u64::MAX - 5,
            obj: 0xDEAD_BEEF_CAFE,
            size: u32::MAX,
            tenant: u16::MAX,
        };
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        assert_eq!(Request::decode(&buf), r);
    }
}
