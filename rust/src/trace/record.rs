//! The request record and trace IO.
//!
//! Binary format v2: little-endian fixed 22-byte records
//! `(ts_us: u64, obj: u64, size: u32, tenant: u16)` after a 16-byte header
//! (`b"ELTC"`, version u32, record count u64). Version-1 files (20-byte
//! records without the tenant column) are still readable; their requests
//! load with `tenant = 0`. CSV is also supported for interoperability
//! (`ts_us,obj,size,tenant` with a header line; the legacy three-column
//! header is accepted on read).

use crate::{ObjectId, Result, TenantId, TimeUs};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"ELTC";
const VERSION: u32 = 2;
const V1_RECORD_BYTES: usize = 20;
const RECORD_BYTES: usize = 22;

/// One trace record: tenant `tenant` requests `obj` of `size` bytes at
/// time `ts`. Single-workload traces use tenant 0 throughout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub ts: TimeUs,
    pub obj: ObjectId,
    pub size: u32,
    pub tenant: TenantId,
}

impl Request {
    /// A single-tenant (tenant 0) request.
    #[inline]
    pub fn new(ts: TimeUs, obj: ObjectId, size: u32) -> Request {
        Request { ts, obj, size, tenant: 0 }
    }

    /// Same request attributed to `tenant`.
    #[inline]
    pub fn with_tenant(mut self, tenant: TenantId) -> Request {
        self.tenant = tenant;
        self
    }

    #[inline]
    pub fn size_bytes(&self) -> u64 {
        self.size as u64
    }

    #[inline]
    fn encode(&self, buf: &mut [u8; RECORD_BYTES]) {
        buf[0..8].copy_from_slice(&self.ts.to_le_bytes());
        buf[8..16].copy_from_slice(&self.obj.to_le_bytes());
        buf[16..20].copy_from_slice(&self.size.to_le_bytes());
        buf[20..22].copy_from_slice(&self.tenant.to_le_bytes());
    }

    #[inline]
    fn decode(buf: &[u8; RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: u16::from_le_bytes(buf[20..22].try_into().unwrap()),
        }
    }

    #[inline]
    fn decode_v1(buf: &[u8; V1_RECORD_BYTES]) -> Request {
        Request {
            ts: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            obj: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            size: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            tenant: 0,
        }
    }
}

/// Streaming binary trace writer (always writes the current version).
pub struct TraceWriter {
    out: BufWriter<File>,
    count: u64,
    path: std::path::PathBuf,
}

impl TraceWriter {
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(&path)?);
        out.write_all(MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u64.to_le_bytes())?; // count patched on finish
        Ok(TraceWriter { out, count: 0, path })
    }

    #[inline]
    pub fn write(&mut self, r: &Request) -> Result<()> {
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        self.out.write_all(&buf)?;
        self.count += 1;
        Ok(())
    }

    /// Flush and patch the record count into the header.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        let count = self.count;
        drop(self.out);
        // Patch header in place.
        use std::io::{Seek, SeekFrom};
        let mut f = std::fs::OpenOptions::new().write(true).open(&self.path)?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&count.to_le_bytes())?;
        Ok(count)
    }
}

/// Streaming binary trace reader (implements [`super::RequestSource`]).
/// Reads both the current 22-byte records and legacy v1 20-byte records.
/// A short read (truncated file, header count larger than the records
/// present) ends the stream; [`TraceReader::check`] surfaces it after
/// the drive loop (the `RequestSource` contract has no error channel).
pub struct TraceReader {
    input: BufReader<File>,
    remaining: u64,
    version: u32,
    error: Option<anyhow::Error>,
}

impl TraceReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut input = BufReader::new(File::open(path.as_ref())?);
        let mut hdr = [0u8; 16];
        input.read_exact(&mut hdr)?;
        anyhow::ensure!(&hdr[0..4] == MAGIC, "not an elastictl trace file");
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        anyhow::ensure!(
            version == 1 || version == VERSION,
            "unsupported trace version {version}"
        );
        let remaining = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        Ok(TraceReader { input, remaining, version, error: None })
    }

    /// Records left to read.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// On-disk format version (1 = legacy tenant-less records).
    pub fn version(&self) -> u32 {
        self.version
    }

    /// Surface (and clear) any IO error that ended the stream early.
    pub fn check(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn fail(&mut self, e: std::io::Error) {
        self.error = Some(anyhow::Error::new(e).context(format!(
            "trace truncated with {} records still expected",
            self.remaining
        )));
        self.remaining = 0;
    }
}

impl super::RequestSource for TraceReader {
    fn next_request(&mut self) -> Option<Request> {
        if self.remaining == 0 {
            return None;
        }
        let req = if self.version == 1 {
            let mut buf = [0u8; V1_RECORD_BYTES];
            match self.input.read_exact(&mut buf) {
                Ok(()) => Request::decode_v1(&buf),
                Err(e) => {
                    self.fail(e);
                    return None;
                }
            }
        } else {
            let mut buf = [0u8; RECORD_BYTES];
            match self.input.read_exact(&mut buf) {
                Ok(()) => Request::decode(&buf),
                Err(e) => {
                    self.fail(e);
                    return None;
                }
            }
        };
        self.remaining -= 1;
        Some(req)
    }
}

/// Write a whole trace to a binary file. Returns the record count.
pub fn write_trace(path: impl AsRef<Path>, reqs: &[Request]) -> Result<u64> {
    let mut w = TraceWriter::create(path)?;
    for r in reqs {
        w.write(r)?;
    }
    w.finish()
}

/// Read a whole binary trace into memory.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    use super::RequestSource;
    let mut r = TraceReader::open(path)?;
    let mut out = Vec::with_capacity(r.remaining() as usize);
    while let Some(req) = r.next_request() {
        out.push(req);
    }
    Ok(out)
}

/// Write a trace as CSV (`ts_us,obj,size,tenant`).
pub fn write_csv(path: impl AsRef<Path>, reqs: &[Request]) -> Result<()> {
    let mut out = BufWriter::new(File::create(path.as_ref())?);
    writeln!(out, "ts_us,obj,size,tenant")?;
    for r in reqs {
        writeln!(out, "{},{},{},{}", r.ts, r.obj, r.size, r.tenant)?;
    }
    out.flush()?;
    Ok(())
}

/// Streaming CSV trace reader (implements [`super::RequestSource`]): same
/// dialect as [`read_csv`] — header line required, the legacy tenant-less
/// `ts_us,obj,size` header accepted (tenant 0), blank lines skipped — in
/// constant memory. A malformed line or a mid-stream IO error ends the
/// stream; [`CsvReader::check`] surfaces it after the drive loop (the
/// `RequestSource` contract has no error channel).
pub struct CsvReader {
    lines: std::io::Lines<BufReader<File>>,
    has_tenant_column: bool,
    /// 1-based data-line counter (the header is line 0), for error reports.
    lineno: usize,
    error: Option<anyhow::Error>,
}

impl CsvReader {
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let mut lines = BufReader::new(File::open(path.as_ref())?).lines();
        // An empty file is an empty trace (matching the pre-streaming
        // reader); a present header must be one of the two known shapes.
        let has_tenant_column = match lines.next().transpose()? {
            None => false,
            Some(header) => {
                let hdr = header.trim();
                let tenant = hdr == "ts_us,obj,size,tenant";
                anyhow::ensure!(
                    tenant || hdr == "ts_us,obj,size",
                    "unexpected CSV header: {header}"
                );
                tenant
            }
        };
        Ok(CsvReader { lines, has_tenant_column, lineno: 0, error: None })
    }

    /// Whether the file carries the v2 tenant column.
    pub fn has_tenant_column(&self) -> bool {
        self.has_tenant_column
    }

    /// Surface (and clear) any error that ended the stream early.
    pub fn check(&mut self) -> Result<()> {
        match self.error.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn parse_line(&self, line: &str) -> Result<Request> {
        let i = self.lineno;
        let mut parts = line.split(',');
        let ts = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing ts"))?
            .trim()
            .parse()?;
        let obj = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing obj"))?
            .trim()
            .parse()?;
        let size = parts
            .next()
            .ok_or_else(|| anyhow::anyhow!("line {i}: missing size"))?
            .trim()
            .parse()?;
        let tenant = if self.has_tenant_column {
            parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("line {i}: missing tenant"))?
                .trim()
                .parse()?
        } else {
            0
        };
        Ok(Request { ts, obj, size, tenant })
    }
}

impl super::RequestSource for CsvReader {
    fn next_request(&mut self) -> Option<Request> {
        if self.error.is_some() {
            return None;
        }
        loop {
            let line = match self.lines.next()? {
                Ok(l) => l,
                Err(e) => {
                    self.error = Some(e.into());
                    return None;
                }
            };
            self.lineno += 1;
            if line.trim().is_empty() {
                continue;
            }
            match self.parse_line(&line) {
                Ok(r) => return Some(r),
                Err(e) => {
                    self.error = Some(e);
                    return None;
                }
            }
        }
    }
}

/// Read a CSV trace into memory (header line required; the legacy
/// tenant-less header `ts_us,obj,size` is accepted and loads every
/// request as tenant 0). Streaming callers use [`CsvReader`] directly.
pub fn read_csv(path: impl AsRef<Path>) -> Result<Vec<Request>> {
    use super::RequestSource;
    let mut r = CsvReader::open(path)?;
    let mut out = Vec::new();
    while let Some(req) = r.next_request() {
        out.push(req);
    }
    r.check()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::RequestSource;

    fn sample_trace(n: u64) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                ts: i * 1000,
                obj: crate::mix64(i) % 100,
                size: (i % 4096 + 1) as u32,
                tenant: (i % 5) as TenantId,
            })
            .collect()
    }

    #[test]
    fn binary_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        let reqs = sample_trace(1000);
        let n = write_trace(&p, &reqs).unwrap();
        assert_eq!(n, 1000);
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn streaming_reader_counts() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        write_trace(&p, &sample_trace(10)).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.remaining(), 10);
        assert_eq!(r.version(), 2);
        assert_eq!(r.take_requests(4).len(), 4);
        assert_eq!(r.remaining(), 6);
        assert_eq!(r.take_requests(100).len(), 6);
        assert!(r.next_request().is_none());
    }

    #[test]
    fn csv_round_trip() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = sample_trace(50);
        write_csv(&p, &reqs).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn legacy_csv_header_reads_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("old.csv");
        std::fs::write(&p, "ts_us,obj,size\n5,7,100\n9,8,200\n").unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(
            back,
            vec![Request::new(5, 7, 100), Request::new(9, 8, 200)]
        );
    }

    #[test]
    fn v1_binary_traces_read_as_tenant_zero() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("v1.bin");
        // Hand-build a version-1 file: header + two 20-byte records.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        for (ts, obj, size) in [(11u64, 3u64, 100u32), (22, 4, 200)] {
            bytes.extend_from_slice(&ts.to_le_bytes());
            bytes.extend_from_slice(&obj.to_le_bytes());
            bytes.extend_from_slice(&size.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        assert_eq!(r.version(), 1);
        let back = r.take_requests(10);
        assert_eq!(
            back,
            vec![Request::new(11, 3, 100), Request::new(22, 4, 200)]
        );
    }

    #[test]
    fn truncated_binary_trace_surfaces_an_error() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.bin");
        write_trace(&p, &sample_trace(10)).unwrap();
        // Chop the file mid-record: 16-byte header + 3 full records + 5
        // stray bytes, while the header still promises 10 records.
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..16 + 3 * RECORD_BYTES + 5]).unwrap();
        let mut r = TraceReader::open(&p).unwrap();
        let got = r.take_requests(100);
        assert_eq!(got.len(), 3, "stream must stop at the torn record");
        let err = r.check().expect_err("truncation must be reported");
        assert!(err.to_string().contains("truncated"), "{err}");
        // check() clears the error once reported.
        r.check().unwrap();
    }

    #[test]
    fn csv_reader_streams_and_surfaces_errors() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = sample_trace(100);
        write_csv(&p, &reqs).unwrap();
        let mut r = CsvReader::open(&p).unwrap();
        assert!(r.has_tenant_column());
        let mut back = Vec::new();
        while let Some(req) = r.next_request() {
            back.push(req);
        }
        r.check().unwrap();
        assert_eq!(back, reqs);

        // A malformed line ends the stream and check() reports it.
        let bad = dir.path().join("bad.csv");
        std::fs::write(&bad, "ts_us,obj,size\n1,2,100\nnot,a,number\n9,9,9\n").unwrap();
        let mut r = CsvReader::open(&bad).unwrap();
        assert!(r.next_request().is_some());
        assert!(r.next_request().is_none(), "stream must stop at the bad line");
        assert!(r.check().is_err());
        // check() clears the error once reported.
        assert!(r.check().is_ok());
        // …and the batch reader propagates the same failure.
        assert!(read_csv(&bad).is_err());

        // An empty file is an empty trace, not a header error.
        let empty = dir.path().join("empty.csv");
        std::fs::write(&empty, "").unwrap();
        let mut r = CsvReader::open(&empty).unwrap();
        assert!(r.next_request().is_none());
        r.check().unwrap();

        // A wrong header is rejected at open.
        let hdr = dir.path().join("hdr.csv");
        std::fs::write(&hdr, "a,b,c\n1,2,3\n").unwrap();
        assert!(CsvReader::open(&hdr).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let p = dir.path().join("bad.bin");
        std::fs::write(&p, b"not a trace file at all").unwrap();
        assert!(TraceReader::open(&p).is_err());
    }

    #[test]
    fn encode_decode_identity() {
        let r = Request {
            ts: u64::MAX - 5,
            obj: 0xDEAD_BEEF_CAFE,
            size: u32::MAX,
            tenant: u16::MAX,
        };
        let mut buf = [0u8; RECORD_BYTES];
        r.encode(&mut buf);
        assert_eq!(Request::decode(&buf), r);
    }
}
