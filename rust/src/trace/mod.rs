//! Request traces: the record format, binary/CSV IO, synthetic workload
//! generators, and trace characterization (Fig. 4).
//!
//! The paper evaluates on anonymized Akamai traces (30 days, 2·10⁹
//! requests, 110 M objects, sizes from bytes to tens of MB, strong diurnal
//! pattern). Those traces are proprietary, so [`SynthGenerator`] generates
//! a synthetic workload matching the two published marginals
//! (rank-frequency and size CDF, Fig. 4) plus the diurnal modulation that
//! drives elasticity; [`IrmGenerator`] generates stationary IRM traffic
//! for validating the stochastic-approximation theory (Proposition 1).
//! See DESIGN.md §3.

mod irm;
mod record;
mod stats;
mod synth;
mod tenant_mux;
mod zipf;

pub use irm::{IrmConfig, IrmGenerator};
pub use record::{
    read_csv, read_items, read_items_csv, read_trace, write_csv, write_items, write_items_csv,
    write_trace, CsvReader, Request, TenantEvent, TenantEventKind, TraceItem, TraceReader,
    TraceWriter,
};
pub use stats::{characterize, TraceStats};
pub use synth::{SynthConfig, SynthGenerator};
pub use tenant_mux::TenantMux;
pub use zipf::Zipf;

use crate::{ObjectId, Result, TimeUs};
use std::path::Path;

/// Anything that yields a time-ordered request stream.
pub trait RequestSource {
    /// Next request, or `None` when the trace is exhausted.
    fn next_request(&mut self) -> Option<Request>;

    /// Next trace *item* — a request, or a tenant lifecycle event from
    /// the format-v3 event lane. The default wraps [`Self::next_request`]
    /// (request-only sources never yield events); event-carrying sources
    /// ([`TraceReader`] on a v3 file, [`EventedVecSource`]) override it.
    /// Event-aware consumers ([`crate::engine::run`]) drive this method;
    /// request-only consumers keep driving `next_request` and never see
    /// the event lane.
    fn next_item(&mut self) -> Option<TraceItem> {
        self.next_request().map(TraceItem::Request)
    }

    /// Drain up to `n` requests into a vector.
    fn take_requests(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            match self.next_request() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }
}

/// An in-memory trace is a source.
pub struct VecSource {
    reqs: std::vec::IntoIter<Request>,
}

impl VecSource {
    pub fn new(reqs: Vec<Request>) -> Self {
        VecSource { reqs: reqs.into_iter() }
    }
}

impl RequestSource for VecSource {
    fn next_request(&mut self) -> Option<Request> {
        self.reqs.next()
    }
}

/// An in-memory item stream (requests + tenant events) — the evented
/// counterpart of [`VecSource`]; `exp fig13` scripts churn through one.
pub struct EventedVecSource {
    items: std::vec::IntoIter<TraceItem>,
}

impl EventedVecSource {
    /// Wrap a pre-built item stream (callers keep it time-ordered).
    pub fn new(items: Vec<TraceItem>) -> Self {
        EventedVecSource { items: items.into_iter() }
    }

    /// Merge a request trace with an event schedule into one time-ordered
    /// stream (see [`merge_items`]).
    pub fn merged(reqs: Vec<Request>, events: Vec<TenantEvent>) -> Self {
        Self::new(merge_items(reqs, events))
    }
}

/// Merge a request trace with an event schedule into one time-ordered
/// item stream (an event at time `t` fires before requests at the same
/// `t`, so a tenant admitted at `t` owns its first request).
pub fn merge_items(reqs: Vec<Request>, mut events: Vec<TenantEvent>) -> Vec<TraceItem> {
    events.sort_by_key(|e| e.ts);
    let mut items = Vec::with_capacity(reqs.len() + events.len());
    let mut ev = events.into_iter().peekable();
    for r in reqs {
        while ev.peek().map(|e| e.ts <= r.ts).unwrap_or(false) {
            items.push(TraceItem::Event(ev.next().unwrap()));
        }
        items.push(TraceItem::Request(r));
    }
    items.extend(ev.map(TraceItem::Event));
    items
}

impl RequestSource for EventedVecSource {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            match self.next_item()? {
                TraceItem::Request(r) => return Some(r),
                TraceItem::Event(_) => continue,
            }
        }
    }

    fn next_item(&mut self) -> Option<TraceItem> {
        self.items.next()
    }
}

/// File-backed streaming source: binary (v1/v2, [`TraceReader`]) or CSV
/// ([`CsvReader`]) picked by extension. Replays a trace in constant
/// memory — this is how `elastictl run` feeds the engine, so a
/// million-user trace never materializes as a `Vec<Request>`.
pub enum FileSource {
    Binary(TraceReader),
    Csv(CsvReader),
}

impl FileSource {
    /// Open `path` (`.csv` → CSV dialect, anything else → binary).
    pub fn open(path: impl AsRef<Path>) -> Result<FileSource> {
        let p = path.as_ref();
        if p.extension().map(|e| e == "csv").unwrap_or(false) {
            Ok(FileSource::Csv(CsvReader::open(p)?))
        } else {
            Ok(FileSource::Binary(TraceReader::open(p)?))
        }
    }

    /// Surface any error that ended the stream early (binary truncation,
    /// CSV parse/IO); call after the drive loop.
    pub fn check(&mut self) -> Result<()> {
        match self {
            FileSource::Binary(r) => r.check(),
            FileSource::Csv(r) => r.check(),
        }
    }
}

impl RequestSource for FileSource {
    fn next_request(&mut self) -> Option<Request> {
        match self {
            FileSource::Binary(r) => r.next_request(),
            FileSource::Csv(r) => r.next_request(),
        }
    }

    fn next_item(&mut self) -> Option<TraceItem> {
        match self {
            FileSource::Binary(r) => r.next_item(),
            FileSource::Csv(r) => r.next_item(),
        }
    }
}

/// Size lookup shared by generators: deterministic per-object size drawn
/// from a heavy-tailed mixture, so the same object always has the same
/// size (as in a real CDN trace).
///
/// The mixture approximates the Fig. 4 size CDF: mostly tens-of-KB web
/// objects, a quarter of mid-size (hundreds of KB) assets, and a small
/// tail of multi-MB downloads, clamped to [64 B, 64 MB].
pub fn object_size(obj: ObjectId, seed: u64) -> u64 {
    let h = crate::mix64(obj ^ seed.rotate_left(17));
    // Split the hash: low bits pick the mixture component, high bits drive
    // the lognormal draw via a Box-Muller-free approximation (sum of
    // uniforms ≈ normal).
    let comp = h % 100;
    let u1 = ((h >> 8) & 0xFFFF) as f64 / 65536.0;
    let u2 = ((h >> 24) & 0xFFFF) as f64 / 65536.0;
    let u3 = ((h >> 40) & 0xFFFF) as f64 / 65536.0;
    // Irwin-Hall(3) standardized: mean 1.5, var 3/12 → z ≈ (sum-1.5)*2
    let z = (u1 + u2 + u3 - 1.5) * 2.0;
    let (median_ln, sigma) = if comp < 70 {
        ((10.0 * 1024.0f64).ln(), 1.2) // ~10 KB web objects
    } else if comp < 95 {
        ((200.0 * 1024.0f64).ln(), 1.0) // ~200 KB assets
    } else {
        ((5.0 * 1024.0 * 1024.0f64).ln(), 0.8) // ~5 MB downloads
    };
    let size = (median_ln + sigma * z).exp();
    (size as u64).clamp(64, 64 * 1024 * 1024)
}

/// Diurnal rate modulation: multiplicative factor in
/// `[1−amplitude, 1+amplitude]` with a 24 h period, peaking mid-day.
#[inline]
pub fn diurnal_factor(t: TimeUs, amplitude: f64) -> f64 {
    let day_frac = (t % crate::DAY) as f64 / crate::DAY as f64;
    // Peak at 14:00, trough at 02:00 (typical CDN vantage-point shape).
    1.0 + amplitude * (2.0 * std::f64::consts::PI * (day_frac - 7.0 / 24.0)).sin()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::{DAY, HOUR};

    #[test]
    fn object_size_is_deterministic_and_bounded() {
        for obj in 0..10_000u64 {
            let s1 = object_size(obj, 7);
            let s2 = object_size(obj, 7);
            assert_eq!(s1, s2);
            assert!((64..=64 * 1024 * 1024).contains(&s1));
        }
        // different seeds give different size assignments
        let diff = (0..1000u64)
            .filter(|&o| object_size(o, 1) != object_size(o, 2))
            .count();
        assert!(diff > 900);
    }

    #[test]
    fn size_distribution_is_heavy_tailed() {
        let sizes: Vec<u64> = (0..100_000u64).map(|o| object_size(o, 42)).collect();
        let mean = sizes.iter().sum::<u64>() as f64 / sizes.len() as f64;
        let mut sorted = sizes.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        // heavy tail: mean well above median
        assert!(mean > 2.0 * median, "mean={mean} median={median}");
        // and the tail reaches into the multi-MB range
        assert!(*sorted.last().unwrap() > 10 * 1024 * 1024);
    }

    #[test]
    fn diurnal_factor_period_and_range() {
        for t in (0..DAY).step_by(HOUR as usize) {
            let f = diurnal_factor(t, 0.8);
            assert!((0.199..=1.801).contains(&f), "f={f}");
            assert!((diurnal_factor(t + DAY, 0.8) - f).abs() < 1e-9);
        }
        // peak afternoon > trough night
        let peak = diurnal_factor(14 * HOUR, 0.8);
        let trough = diurnal_factor(2 * HOUR, 0.8);
        assert!(peak > 1.5 && trough < 0.5);
    }

    #[test]
    fn vec_source_drains() {
        let reqs = vec![Request::new(0, 1, 10), Request::new(1, 2, 20)];
        let mut src = VecSource::new(reqs);
        assert_eq!(src.take_requests(5).len(), 2);
        assert!(src.next_request().is_none());
    }

    #[test]
    fn evented_source_merges_events_before_coincident_requests() {
        let reqs = vec![
            Request::new(1, 1, 10),
            Request::new(5, 2, 10),
            Request::new(9, 3, 10),
        ];
        let events = vec![TenantEvent::retire(20, 1), TenantEvent::admit(5, 1)];
        let mut src = EventedVecSource::merged(reqs, events);
        let mut kinds = Vec::new();
        while let Some(item) = src.next_item() {
            kinds.push(match item {
                TraceItem::Request(r) => format!("r{}", r.ts),
                TraceItem::Event(e) => format!("e{}", e.ts),
            });
        }
        assert_eq!(kinds, vec!["r1", "e5", "r5", "r9", "e20"]);
        // next_request skips events.
        let mut src = EventedVecSource::merged(
            vec![Request::new(1, 1, 10)],
            vec![TenantEvent::admit(0, 2)],
        );
        assert_eq!(src.next_request(), Some(Request::new(1, 1, 10)));
        assert!(src.next_request().is_none());
    }

    #[test]
    fn file_source_dispatches_on_extension() {
        let dir = crate::util::tempdir::tempdir().unwrap();
        let reqs = vec![Request::new(0, 1, 10), Request::new(5, 2, 20)];

        let bin = dir.path().join("t.bin");
        write_trace(&bin, &reqs).unwrap();
        let mut src = FileSource::open(&bin).unwrap();
        assert!(matches!(src, FileSource::Binary(_)));
        assert_eq!(src.take_requests(10), reqs);
        src.check().unwrap();

        let csv = dir.path().join("t.csv");
        write_csv(&csv, &reqs).unwrap();
        let mut src = FileSource::open(&csv).unwrap();
        assert!(matches!(src, FileSource::Csv(_)));
        assert_eq!(src.take_requests(10), reqs);
        src.check().unwrap();

        assert!(FileSource::open(dir.path().join("missing.bin")).is_err());
    }
}
