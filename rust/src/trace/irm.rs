//! Stationary IRM (Independent Reference Model) trace generator — the
//! arrival pattern under which Proposition 1 holds: Poisson aggregate
//! arrivals, each request independently for object `i` with probability
//! `λ_i / Σλ_j` (§4.1). Used to validate controller convergence and the
//! analytic planner against theory.

use super::{object_size, Request, RequestSource, Zipf};
use crate::{TimeUs, SECOND};
use crate::util::rng::Pcg;

/// IRM generator parameters.
#[derive(Debug, Clone)]
pub struct IrmConfig {
    /// Catalogue size N.
    pub catalogue: u64,
    /// Zipf exponent shaping the per-object rates λ_i.
    pub alpha: f64,
    /// Aggregate Poisson rate Σλ_i, requests per second.
    pub total_rate: f64,
    /// Trace duration (µs).
    pub duration: TimeUs,
    pub seed: u64,
}

impl IrmConfig {
    pub fn small() -> Self {
        IrmConfig {
            catalogue: 10_000,
            alpha: 0.9,
            total_rate: 500.0,
            duration: 2 * crate::HOUR,
            seed: 11,
        }
    }

    /// Per-object arrival rate λ_i for rank `i` (1-based), requests/s.
    pub fn lambda_of_rank(&self, rank: u64) -> f64 {
        let z = Zipf::new(self.catalogue, self.alpha);
        self.total_rate * z.pmf(rank)
    }
}

/// Streaming IRM source.
pub struct IrmGenerator {
    cfg: IrmConfig,
    zipf: Zipf,
    rng: Pcg,
    now: TimeUs,
    rate_per_us: f64,
}

impl IrmGenerator {
    pub fn new(cfg: IrmConfig) -> Self {
        IrmGenerator {
            zipf: Zipf::new(cfg.catalogue, cfg.alpha),
            rng: Pcg::seed_from_u64(cfg.seed),
            now: 0,
            rate_per_us: cfg.total_rate / SECOND as f64,
            cfg,
        }
    }

    pub fn config(&self) -> &IrmConfig {
        &self.cfg
    }

    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.next_request() {
            out.push(r);
        }
        out
    }
}

impl RequestSource for IrmGenerator {
    fn next_request(&mut self) -> Option<Request> {
        let u: f64 = self.rng.f64().max(1e-300);
        let dt = (-u.ln() / self.rate_per_us).ceil() as TimeUs;
        self.now = self.now.saturating_add(dt.max(1));
        if self.now >= self.cfg.duration {
            return None;
        }
        let obj = self.zipf.sample(&mut self.rng);
        let size = object_size(obj, self.cfg.seed) as u32;
        Some(Request::new(self.now, obj, size))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn aggregate_rate_matches() {
        let cfg = IrmConfig::small();
        let dur_s = cfg.duration as f64 / SECOND as f64;
        let expect = cfg.total_rate * dur_s;
        let n = IrmGenerator::new(cfg).generate().len() as f64;
        assert!((n - expect).abs() / expect < 0.05, "n={n} expect={expect}");
    }

    #[test]
    fn per_object_rates_follow_zipf() {
        let cfg = IrmConfig { catalogue: 100, ..IrmConfig::small() };
        let lam1 = cfg.lambda_of_rank(1);
        let lam10 = cfg.lambda_of_rank(10);
        // λ_1/λ_10 = 10^alpha
        assert!((lam1 / lam10 - 10f64.powf(cfg.alpha)).abs() < 1e-6);

        let trace = IrmGenerator::new(cfg.clone()).generate();
        let mut counts: HashMap<u64, u64> = HashMap::new();
        for r in &trace {
            *counts.entry(r.obj).or_default() += 1;
        }
        let dur_s = cfg.duration as f64 / SECOND as f64;
        let emp1 = *counts.get(&1).unwrap_or(&0) as f64 / dur_s;
        assert!(
            (emp1 - lam1).abs() / lam1 < 0.15,
            "emp={emp1} lam={lam1}"
        );
    }

    #[test]
    fn interarrivals_are_memoryless() {
        // Coefficient of variation of exponential inter-arrivals is 1.
        let cfg = IrmConfig::small();
        let trace = IrmGenerator::new(cfg).generate();
        let gaps: Vec<f64> = trace.windows(2).map(|w| (w[1].ts - w[0].ts) as f64).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.05, "cv={cv}");
    }
}
