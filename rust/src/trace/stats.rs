//! Trace characterization — regenerates Fig. 4 of the paper: requests per
//! object ordered by rank (left) and the cumulative fraction of requests
//! for objects up to a given size (right).

use super::Request;
use crate::metrics::LogHistogram;
use std::collections::HashMap;

/// Aggregate statistics of a trace.
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub requests: u64,
    pub distinct_objects: u64,
    pub total_bytes_requested: u64,
    /// Sum of sizes of distinct objects (the footprint an infinite cache
    /// would need).
    pub footprint_bytes: u64,
    pub duration_us: u64,
    /// Request counts ordered by popularity rank (descending) — Fig. 4 left.
    pub rank_frequency: Vec<u64>,
    /// Request-weighted size CDF points `(size_edge, fraction)` — Fig. 4
    /// right.
    pub size_cdf: Vec<(u64, f64)>,
    pub min_size: u64,
    pub max_size: u64,
    pub mean_size: f64,
}

impl TraceStats {
    /// Mean request rate over the trace, requests/s.
    pub fn mean_rate(&self) -> f64 {
        if self.duration_us == 0 {
            0.0
        } else {
            self.requests as f64 / (self.duration_us as f64 / crate::SECOND as f64)
        }
    }

    /// Requests per distinct object.
    pub fn reqs_per_object(&self) -> f64 {
        if self.distinct_objects == 0 {
            0.0
        } else {
            self.requests as f64 / self.distinct_objects as f64
        }
    }

    /// Fit a Zipf exponent to the head of the rank-frequency curve by
    /// log-log least squares over the top `k` ranks.
    pub fn fitted_zipf_alpha(&self, k: usize) -> Option<f64> {
        let k = k.min(self.rank_frequency.len());
        if k < 3 {
            return None;
        }
        let pts: Vec<(f64, f64)> = self.rank_frequency[..k]
            .iter()
            .enumerate()
            .filter(|(_, &f)| f > 0)
            .map(|(i, &f)| (((i + 1) as f64).ln(), (f as f64).ln()))
            .collect();
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < 1e-12 {
            return None;
        }
        let slope = (n * sxy - sx * sy) / denom;
        Some(-slope)
    }
}

/// Compute [`TraceStats`] over a trace slice.
pub fn characterize(trace: &[Request]) -> TraceStats {
    let mut counts: HashMap<u64, u64> = HashMap::new();
    let mut sizes: HashMap<u64, u64> = HashMap::new();
    let mut total_bytes = 0u64;
    let mut size_hist = LogHistogram::new(1.3, 1 << 40);
    let (mut min_size, mut max_size) = (u64::MAX, 0u64);
    for r in trace {
        *counts.entry(r.obj).or_default() += 1;
        sizes.entry(r.obj).or_insert(r.size_bytes());
        total_bytes += r.size_bytes();
        size_hist.inc(r.size_bytes());
        min_size = min_size.min(r.size_bytes());
        max_size = max_size.max(r.size_bytes());
    }
    let mut rank_frequency: Vec<u64> = counts.values().copied().collect();
    rank_frequency.sort_unstable_by(|a, b| b.cmp(a));
    let duration_us = match (trace.first(), trace.last()) {
        (Some(a), Some(b)) => b.ts.saturating_sub(a.ts),
        _ => 0,
    };
    let footprint: u64 = sizes.values().sum();
    let requests = trace.len() as u64;
    TraceStats {
        requests,
        distinct_objects: counts.len() as u64,
        total_bytes_requested: total_bytes,
        footprint_bytes: footprint,
        duration_us,
        rank_frequency,
        size_cdf: size_hist.cdf(),
        min_size: if requests == 0 { 0 } else { min_size },
        max_size,
        mean_size: if requests == 0 {
            0.0
        } else {
            total_bytes as f64 / requests as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{SynthConfig, SynthGenerator};

    #[test]
    fn characterize_counts() {
        let trace = vec![
            Request::new(0, 1, 100),
            Request::new(10, 1, 100),
            Request::new(20, 2, 50),
        ];
        let s = characterize(&trace);
        assert_eq!(s.requests, 3);
        assert_eq!(s.distinct_objects, 2);
        assert_eq!(s.total_bytes_requested, 250);
        assert_eq!(s.footprint_bytes, 150);
        assert_eq!(s.duration_us, 20);
        assert_eq!(s.rank_frequency, vec![2, 1]);
        assert_eq!(s.min_size, 50);
        assert_eq!(s.max_size, 100);
    }

    #[test]
    fn empty_trace_is_safe() {
        let s = characterize(&[]);
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_rate(), 0.0);
        assert_eq!(s.reqs_per_object(), 0.0);
        assert!(s.fitted_zipf_alpha(100).is_none());
    }

    #[test]
    fn fitted_alpha_recovers_generator_exponent() {
        let mut cfg = SynthConfig::tiny();
        cfg.alpha = 0.9;
        cfg.mean_rate = 400.0;
        let trace = SynthGenerator::new(cfg).generate();
        let s = characterize(&trace);
        let alpha = s.fitted_zipf_alpha(50).unwrap();
        assert!(
            (alpha - 0.9).abs() < 0.25,
            "fitted alpha={alpha} expected ~0.9"
        );
    }

    #[test]
    fn size_cdf_monotone_and_normalized() {
        let trace = SynthGenerator::new(SynthConfig::tiny()).generate();
        let s = characterize(&trace);
        let cdf = &s.size_cdf;
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[1].1 >= w[0].1);
            assert!(w[1].0 > w[0].0);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }
}
