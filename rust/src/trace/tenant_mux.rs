//! Multi-tenant workload multiplexer: interleaves N per-tenant request
//! sources into one time-ordered stream, tagging every request with its
//! tenant id.
//!
//! Each tenant keeps its own generator (its own Zipf exponent, rate,
//! churn, diurnal amplitude, …), so the aggregate stream exhibits the
//! cross-tenant heterogeneity the multi-tenant provisioning layer
//! ([`crate::tenant`]) is designed to exploit. Object ids stay
//! *tenant-local* (two tenants may both request object 7); consumers that
//! share physical state across tenants scope them via
//! [`crate::tenant::scoped_object`].

use super::{Request, RequestSource};
use crate::TenantId;

/// K-way merge of per-tenant request sources, ordered by timestamp.
pub struct TenantMux {
    streams: Vec<Stream>,
}

struct Stream {
    tenant: TenantId,
    source: Box<dyn RequestSource>,
    /// Next request from this stream, if any (already tenant-tagged).
    head: Option<Request>,
}

impl TenantMux {
    pub fn new() -> Self {
        TenantMux { streams: Vec::new() }
    }

    /// Register `source` as tenant `tenant`'s request stream. Requests it
    /// yields are re-tagged with `tenant` regardless of their own field.
    pub fn add(&mut self, tenant: TenantId, source: Box<dyn RequestSource>) {
        let mut stream = Stream { tenant, source, head: None };
        stream.refill();
        self.streams.push(stream);
    }

    /// Number of registered tenant streams (exhausted ones included).
    pub fn streams(&self) -> usize {
        self.streams.len()
    }

    /// Drain the whole merged stream into a vector.
    pub fn generate(mut self) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.next_request() {
            out.push(r);
        }
        out
    }
}

impl Default for TenantMux {
    fn default() -> Self {
        Self::new()
    }
}

impl Stream {
    fn refill(&mut self) {
        self.head = self
            .source
            .next_request()
            .map(|r| r.with_tenant(self.tenant));
    }
}

impl RequestSource for TenantMux {
    fn next_request(&mut self) -> Option<Request> {
        // Linear scan over the heads: the stream count is the tenant count
        // (single digits), so this beats a heap in practice.
        let mut best: Option<usize> = None;
        for (i, s) in self.streams.iter().enumerate() {
            if let Some(h) = &s.head {
                match best {
                    Some(b) if self.streams[b].head.as_ref().unwrap().ts <= h.ts => {}
                    _ => best = Some(i),
                }
            }
        }
        let i = best?;
        let out = self.streams[i].head.take();
        self.streams[i].refill();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{IrmConfig, IrmGenerator, VecSource};

    fn fixed_stream(tenant_marker: u64, times: &[u64]) -> Box<dyn RequestSource> {
        let reqs = times
            .iter()
            .map(|&t| Request::new(t, tenant_marker, 10))
            .collect();
        Box::new(VecSource::new(reqs))
    }

    #[test]
    fn merges_in_timestamp_order_and_tags_tenants() {
        let mut mux = TenantMux::new();
        mux.add(0, fixed_stream(100, &[1, 5, 9]));
        mux.add(1, fixed_stream(200, &[2, 3, 10]));
        mux.add(7, fixed_stream(300, &[4]));
        assert_eq!(mux.streams(), 3);
        let merged = mux.generate();
        let ts: Vec<u64> = merged.iter().map(|r| r.ts).collect();
        assert_eq!(ts, vec![1, 2, 3, 4, 5, 9, 10]);
        for r in &merged {
            let expect = match r.obj {
                100 => 0,
                200 => 1,
                300 => 7,
                other => panic!("unexpected obj {other}"),
            };
            assert_eq!(r.tenant, expect, "request {r:?}");
        }
    }

    #[test]
    fn empty_mux_is_exhausted() {
        let mut mux = TenantMux::new();
        assert!(mux.next_request().is_none());
        mux.add(0, Box::new(VecSource::new(Vec::new())));
        assert!(mux.next_request().is_none());
    }

    #[test]
    fn retags_source_tenant_field() {
        let reqs = vec![Request::new(1, 1, 10).with_tenant(9)];
        let mut mux = TenantMux::new();
        mux.add(2, Box::new(VecSource::new(reqs)));
        let out = mux.generate();
        assert_eq!(out[0].tenant, 2);
    }

    #[test]
    fn interleaves_real_generators() {
        let mut mux = TenantMux::new();
        for t in 0..3u16 {
            let cfg = IrmConfig {
                catalogue: 500,
                total_rate: 50.0,
                duration: crate::MINUTE * 5,
                seed: 17 + t as u64,
                ..IrmConfig::small()
            };
            mux.add(t, Box::new(IrmGenerator::new(cfg)));
        }
        let merged = mux.generate();
        assert!(merged.len() > 100);
        for w in merged.windows(2) {
            assert!(w[1].ts >= w[0].ts, "out of order: {:?} {:?}", w[0], w[1]);
        }
        let mut seen = std::collections::HashSet::new();
        for r in &merged {
            seen.insert(r.tenant);
        }
        assert_eq!(seen.len(), 3);
    }
}
