//! Zipf(α) rank sampler with O(1) amortized sampling via the rejection
//! method of [Jim Gray et al., "Quickly Generating Billion-Record
//! Synthetic Databases"] — no O(N) table, so catalogues of 10⁶–10⁸
//! objects are cheap to sample from.

use crate::util::rng::Pcg;

/// Zipf distribution over ranks `1..=n` with exponent `alpha > 0`:
/// `P(rank = k) ∝ k^-alpha`.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    // Precomputed constants of the rejection sampler.
    t: f64,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n >= 1, "catalogue must be non-empty");
        assert!(alpha > 0.0, "alpha must be positive");
        // t = (n^(1-alpha) - alpha) / (1 - alpha) for alpha != 1,
        //     1 + ln(n) for alpha == 1 (integral of the envelope).
        let t = if (alpha - 1.0).abs() < 1e-12 {
            1.0 + (n as f64).ln()
        } else {
            ((n as f64).powf(1.0 - alpha) - alpha) / (1.0 - alpha)
        };
        Zipf { n, alpha, t }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Inverse of the envelope CDF.
    #[inline]
    fn inv_cdf(&self, p: f64) -> f64 {
        let pt = p * self.t;
        if pt <= 1.0 {
            pt
        } else if (self.alpha - 1.0).abs() < 1e-12 {
            (pt - 1.0 + 1.0f64.ln()).exp() // e^(pt-1)
        } else {
            (pt * (1.0 - self.alpha) + self.alpha).powf(1.0 / (1.0 - self.alpha))
        }
    }

    /// Sample a rank in `1..=n`.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg) -> u64 {
        loop {
            let p: f64 = rng.f64();
            let x = self.inv_cdf(p);
            let k = (x + 1.0).floor().clamp(1.0, self.n as f64);
            // Accept with probability proportional to the ratio of the true
            // pmf to the envelope density at x.
            let ratio = (k.powf(-self.alpha))
                / if x <= 1.0 { 1.0 } else { x.powf(-self.alpha) };
            let accept: f64 = rng.f64();
            if accept < ratio {
                return k as u64;
            }
        }
    }

    /// Exact probability of rank `k` (O(n) normalization on first call —
    /// for tests and for the analytic planner's bucketing, not for
    /// sampling).
    pub fn pmf(&self, k: u64) -> f64 {
        assert!(k >= 1 && k <= self.n);
        (k as f64).powf(-self.alpha) / self.harmonic()
    }

    /// Generalized harmonic number `H_{n,alpha}`.
    pub fn harmonic(&self) -> f64 {
        (1..=self.n).map(|k| (k as f64).powf(-self.alpha)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let z = Zipf::new(1000, 0.9);
        let mut rng = Pcg::seed_from_u64(1);
        for _ in 0..10_000 {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
        }
    }

    #[test]
    fn empirical_matches_pmf() {
        for alpha in [0.7, 1.0, 1.3] {
            let n = 200u64;
            let z = Zipf::new(n, alpha);
            let mut rng = Pcg::seed_from_u64(42);
            let trials = 400_000;
            let mut counts = vec![0u64; n as usize + 1];
            for _ in 0..trials {
                counts[z.sample(&mut rng) as usize] += 1;
            }
            // Check the head ranks against the exact pmf (relative error).
            for k in [1u64, 2, 5, 10, 50] {
                let emp = counts[k as usize] as f64 / trials as f64;
                let exact = z.pmf(k);
                let rel = (emp - exact).abs() / exact;
                assert!(
                    rel < 0.08,
                    "alpha={alpha} k={k}: emp={emp:.5} exact={exact:.5} rel={rel:.3}"
                );
            }
        }
    }

    #[test]
    fn pmf_normalizes() {
        let z = Zipf::new(500, 0.9);
        let sum: f64 = (1..=500).map(|k| z.pmf(k)).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn head_is_heavier_with_larger_alpha() {
        let z1 = Zipf::new(1000, 0.6);
        let z2 = Zipf::new(1000, 1.2);
        assert!(z2.pmf(1) > z1.pmf(1));
    }

    #[test]
    #[should_panic]
    fn rejects_zero_alpha() {
        let _ = Zipf::new(10, 0.0);
    }
}
