//! Lightweight metrics: counters, time series, log-scale histograms,
//! percentile summaries, CSV export. Everything on the request path is
//! allocation-free; series sampling happens at epoch granularity.

mod histogram;
mod series;

pub use histogram::LogHistogram;
pub use series::{merged_csv, TimeSeries};

use std::fmt::Write as _;

/// Hit/miss counters for one cache (physical or virtual).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HitMiss {
    pub hits: u64,
    pub misses: u64,
}

impl HitMiss {
    #[inline]
    pub fn record(&mut self, hit: bool) {
        if hit {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
    }

    #[inline]
    pub fn total(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit ratio in [0,1]; 0 for an empty counter.
    #[inline]
    pub fn hit_ratio(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.hits as f64 / self.total() as f64
        }
    }

    /// Miss ratio in [0,1]; 1 for an empty counter (pessimistic).
    #[inline]
    pub fn miss_ratio(&self) -> f64 {
        if self.total() == 0 {
            1.0
        } else {
            self.misses as f64 / self.total() as f64
        }
    }

    /// Hit ratio, or `None` for an empty counter — distinguishing
    /// "no traffic yet" from a true 0% hit ratio (which
    /// [`HitMiss::hit_ratio`] conflates).
    #[inline]
    pub fn try_hit_ratio(&self) -> Option<f64> {
        if self.total() == 0 {
            None
        } else {
            Some(self.hits as f64 / self.total() as f64)
        }
    }

    /// Miss ratio, or `None` for an empty counter.
    #[inline]
    pub fn try_miss_ratio(&self) -> Option<f64> {
        if self.total() == 0 {
            None
        } else {
            Some(self.misses as f64 / self.total() as f64)
        }
    }

    /// Merge another counter into this one.
    pub fn merge(&mut self, other: &HitMiss) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

/// Exponentially weighted moving average.
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1]");
        Ewma { alpha, value: None }
    }

    #[inline]
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    #[inline]
    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Mean / min / max / percentile summary over a sample batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary. Returns `None` for empty input.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        Some(Summary {
            count: sorted.len(),
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p50: pct(0.50),
            p90: pct(0.90),
            p99: pct(0.99),
        })
    }
}

/// Render rows of (label, values...) as aligned CSV text.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{}", header.join(","));
    for row in rows {
        let _ = writeln!(out, "{}", row.join(","));
    }
    out
}

/// Write CSV text to a file, creating parent directories.
pub fn write_csv(
    path: impl AsRef<std::path::Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> crate::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_csv(header, rows))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_ratios() {
        let mut hm = HitMiss::default();
        assert_eq!(hm.hit_ratio(), 0.0);
        assert_eq!(hm.miss_ratio(), 1.0);
        assert_eq!(hm.try_hit_ratio(), None, "empty counter has no ratio");
        assert_eq!(hm.try_miss_ratio(), None);
        for i in 0..10 {
            hm.record(i % 4 != 0); // 3 hits per 4
        }
        assert_eq!(hm.total(), 10);
        assert_eq!(hm.misses, 3);
        assert!((hm.hit_ratio() - 0.7).abs() < 1e-12);
        assert_eq!(hm.try_hit_ratio(), Some(hm.hit_ratio()));
        assert_eq!(hm.try_miss_ratio(), Some(hm.miss_ratio()));
        let mut other = HitMiss { hits: 1, misses: 1 };
        other.merge(&hm);
        assert_eq!(other.total(), 12);
    }

    #[test]
    fn ewma_tracks() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(0.0), 5.0);
        assert_eq!(e.update(0.0), 2.5);
        e.reset();
        assert_eq!(e.get(), None);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_bad_alpha() {
        let _ = Ewma::new(0.0);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs).unwrap();
        assert_eq!(s.count, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn csv_render() {
        let rows = vec![vec!["a".into(), "1".into()], vec!["b".into(), "2".into()]];
        let text = to_csv(&["k", "v"], &rows);
        assert_eq!(text, "k,v\na,1\nb,2\n");
    }
}
