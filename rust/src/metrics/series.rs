//! Time series: (timestamp, value) samples with cumulative helpers and CSV
//! export. Used for TTL-over-time (Fig. 5), cumulative costs (Figs. 6–8)
//! and balance metrics (Fig. 9).

use crate::{us_to_secs, TimeUs};

/// A named series of `(t, v)` samples, `t` in microseconds.
#[derive(Debug, Clone, Default)]
pub struct TimeSeries {
    pub name: String,
    samples: Vec<(TimeUs, f64)>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>) -> Self {
        TimeSeries { name: name.into(), samples: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, t: TimeUs, v: f64) {
        self.samples.push((t, v));
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[(TimeUs, f64)] {
        &self.samples
    }

    pub fn last(&self) -> Option<(TimeUs, f64)> {
        self.samples.last().copied()
    }

    /// Running cumulative sum of the values (same timestamps).
    pub fn cumulative(&self) -> TimeSeries {
        let mut out = TimeSeries::new(format!("{}_cum", self.name));
        let mut acc = 0.0;
        for &(t, v) in &self.samples {
            acc += v;
            out.push(t, acc);
        }
        out
    }

    /// Value at or before `t` (step interpolation); `None` before the first
    /// sample.
    pub fn at(&self, t: TimeUs) -> Option<f64> {
        match self.samples.binary_search_by_key(&t, |&(ts, _)| ts) {
            Ok(i) => Some(self.samples[i].1),
            Err(0) => None,
            Err(i) => Some(self.samples[i - 1].1),
        }
    }

    /// Time integral ∫ v dt over the sampled range using step
    /// interpolation, in value·seconds. This is how the ideal TTL cache's
    /// instantaneous-occupancy bill is computed.
    pub fn integral(&self) -> f64 {
        let mut acc = 0.0;
        for w in self.samples.windows(2) {
            let (t0, v0) = w[0];
            let (t1, _) = w[1];
            acc += v0 * (us_to_secs(t1) - us_to_secs(t0));
        }
        acc
    }

    /// Max value over the series, `None` if empty.
    pub fn max(&self) -> Option<f64> {
        self.samples
            .iter()
            .map(|&(_, v)| v)
            .fold(None, |m, v| Some(m.map_or(v, |m: f64| m.max(v))))
    }

    /// Mean value (unweighted by time).
    pub fn mean(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.samples.iter().map(|&(_, v)| v).sum::<f64>() / self.samples.len() as f64)
        }
    }

    /// Render as CSV rows `t_secs,value`.
    pub fn csv_rows(&self) -> Vec<Vec<String>> {
        self.samples
            .iter()
            .map(|&(t, v)| vec![format!("{:.3}", us_to_secs(t)), format!("{v:.9e}")])
            .collect()
    }

    /// Downsample to at most `n` evenly spaced points (keeps first + last).
    pub fn downsample(&self, n: usize) -> TimeSeries {
        let mut out = TimeSeries::new(self.name.clone());
        if self.samples.len() <= n || n < 2 {
            out.samples = self.samples.clone();
            return out;
        }
        let step = (self.samples.len() - 1) as f64 / (n - 1) as f64;
        for i in 0..n {
            let idx = (i as f64 * step).round() as usize;
            out.samples.push(self.samples[idx.min(self.samples.len() - 1)]);
        }
        out
    }
}

/// Align several series on the union of their timestamps (step
/// interpolation) and render a combined CSV (`t_secs,<name1>,<name2>,…`).
pub fn merged_csv(series: &[&TimeSeries]) -> String {
    let mut ts: Vec<TimeUs> = series
        .iter()
        .flat_map(|s| s.samples().iter().map(|&(t, _)| t))
        .collect();
    ts.sort_unstable();
    ts.dedup();
    let mut header = vec!["t_secs".to_string()];
    header.extend(series.iter().map(|s| s.name.clone()));
    let mut out = header.join(",");
    out.push('\n');
    for t in ts {
        let mut row = vec![format!("{:.3}", us_to_secs(t))];
        for s in series {
            row.push(match s.at(t) {
                Some(v) => format!("{v:.9e}"),
                None => String::new(),
            });
        }
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::SECOND;

    #[test]
    fn cumulative_and_integral() {
        let mut s = TimeSeries::new("x");
        s.push(0, 1.0);
        s.push(SECOND, 2.0);
        s.push(3 * SECOND, 4.0);
        let c = s.cumulative();
        assert_eq!(c.last().unwrap().1, 7.0);
        // ∫ = 1*1 + 2*2 = 5 (step interp, last sample contributes nothing)
        assert!((s.integral() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn step_lookup() {
        let mut s = TimeSeries::new("x");
        s.push(10, 1.0);
        s.push(20, 2.0);
        assert_eq!(s.at(5), None);
        assert_eq!(s.at(10), Some(1.0));
        assert_eq!(s.at(15), Some(1.0));
        assert_eq!(s.at(20), Some(2.0));
        assert_eq!(s.at(1000), Some(2.0));
    }

    #[test]
    fn stats_and_downsample() {
        let mut s = TimeSeries::new("x");
        for i in 0..101u64 {
            s.push(i, i as f64);
        }
        assert_eq!(s.max(), Some(100.0));
        assert!((s.mean().unwrap() - 50.0).abs() < 1e-9);
        let d = s.downsample(11);
        assert_eq!(d.len(), 11);
        assert_eq!(d.samples()[0].1, 0.0);
        assert_eq!(d.samples()[10].1, 100.0);
        // n >= len keeps everything
        assert_eq!(s.downsample(1000).len(), 101);
    }

    #[test]
    fn merged_csv_aligns() {
        let mut a = TimeSeries::new("a");
        a.push(0, 1.0);
        a.push(2 * SECOND, 3.0);
        let mut b = TimeSeries::new("b");
        b.push(SECOND, 5.0);
        let text = merged_csv(&[&a, &b]);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "t_secs,a,b");
        assert_eq!(lines.len(), 4);
        assert!(lines[1].starts_with("0.000,1"));
        assert!(lines[1].ends_with(",")); // b missing before its first sample
    }
}
