//! Log-scale histogram over `u64` magnitudes (bytes, reuse distances,
//! latencies). Constant-time insert; used by the MRC machinery and by the
//! trace characterization of Fig. 4.

/// Histogram with logarithmically spaced buckets: bucket `i` covers
/// `[base^i, base^(i+1))`, with a dedicated zero bucket.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    base: f64,
    counts: Vec<f64>,
    zero: f64,
    /// Values beyond the last bucket (counted, reported as "overflow").
    overflow: f64,
    total: f64,
}

impl LogHistogram {
    /// `base` > 1 controls resolution (e.g. 2.0 → power-of-two buckets,
    /// 1.2 → ~4 buckets per octave); `max_value` fixes the bucket count.
    pub fn new(base: f64, max_value: u64) -> Self {
        assert!(base > 1.0);
        let nbuckets = ((max_value.max(2) as f64).ln() / base.ln()).ceil() as usize + 1;
        LogHistogram {
            base,
            counts: vec![0.0; nbuckets],
            zero: 0.0,
            overflow: 0.0,
            total: 0.0,
        }
    }

    /// Rebuild a histogram from raw per-bucket weights (the telemetry
    /// timer's atomic buckets snapshot through this so quantile / CDF
    /// logic lives in one place).
    pub fn from_parts(base: f64, counts: Vec<f64>, zero: f64, overflow: f64) -> Self {
        assert!(base > 1.0);
        let total = zero + overflow + counts.iter().sum::<f64>();
        LogHistogram { base, counts, zero, overflow, total }
    }

    /// Fold `other`'s weights into `self`. Both histograms must share a
    /// bucket layout (same base and bucket count).
    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.base.to_bits(), other.base.to_bits(), "histogram base mismatch");
        assert_eq!(self.counts.len(), other.counts.len(), "histogram bucket mismatch");
        self.zero += other.zero;
        self.overflow += other.overflow;
        self.total += other.total;
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
    }

    #[inline]
    fn bucket_of(&self, v: u64) -> Option<usize> {
        if v == 0 {
            return None;
        }
        let idx = (v as f64).ln() / self.base.ln();
        Some(idx as usize)
    }

    /// Insert `v` with weight `w`.
    #[inline]
    pub fn add(&mut self, v: u64, w: f64) {
        self.total += w;
        match self.bucket_of(v) {
            None => self.zero += w,
            Some(i) if i < self.counts.len() => self.counts[i] += w,
            Some(_) => self.overflow += w,
        }
    }

    /// Insert with weight 1.
    #[inline]
    pub fn inc(&mut self, v: u64) {
        self.add(v, 1.0);
    }

    pub fn total(&self) -> f64 {
        self.total
    }

    pub fn overflow(&self) -> f64 {
        self.overflow
    }

    /// Lower edge of bucket `i`.
    pub fn bucket_lo(&self, i: usize) -> u64 {
        self.base.powi(i as i32) as u64
    }

    /// Weight of values ≤ `v` (inclusive of the full bucket containing `v`
    /// — the histogram's resolution limit).
    pub fn cumulative_le(&self, v: u64) -> f64 {
        let mut acc = self.zero;
        if let Some(b) = self.bucket_of(v) {
            for i in 0..=b.min(self.counts.len().saturating_sub(1)) {
                acc += self.counts[i];
            }
        }
        acc
    }

    /// Weight of values strictly greater than bucket(v)'s upper edge, plus
    /// overflow. `cumulative_gt(v) = total − cumulative_le(v)`.
    pub fn cumulative_gt(&self, v: u64) -> f64 {
        self.total - self.cumulative_le(v)
    }

    /// Empirical CDF evaluated at each bucket edge:
    /// returns (edge_value, fraction ≤ edge).
    pub fn cdf(&self) -> Vec<(u64, f64)> {
        let mut out: Vec<(u64, f64)> = Vec::with_capacity(self.counts.len());
        let mut acc = self.zero;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if self.total > 0.0 {
                let edge = self.bucket_lo(i + 1);
                // Small buckets can share an integer edge (base^i truncates);
                // merge them so the CDF edges are strictly increasing.
                match out.last_mut() {
                    Some(last) if last.0 == edge => last.1 = acc / self.total,
                    _ => out.push((edge, acc / self.total)),
                }
            }
        }
        out
    }

    /// Scale every stored weight by `f` (used for epoch decay in the MRC
    /// scaler so sizing tracks diurnal popularity changes).
    pub fn decay(&mut self, f: f64) {
        assert!((0.0..=1.0).contains(&f));
        self.zero *= f;
        self.overflow *= f;
        for c in &mut self.counts {
            *c *= f;
        }
        self.total *= f;
    }

    /// Reset all counts.
    pub fn clear(&mut self) {
        self.zero = 0.0;
        self.overflow = 0.0;
        self.total = 0.0;
        for c in &mut self.counts {
            *c = 0.0;
        }
    }

    pub fn num_buckets(&self) -> usize {
        self.counts.len()
    }

    /// Value at quantile `q` ∈ [0, 1], linearly interpolated within the
    /// bucket where the cumulative weight crosses `q × total`.
    ///
    /// Resolution is bounded by the bucket width: the answer is exact to
    /// within a factor of `base` of the true sample quantile. Ranks
    /// landing in the zero bucket return 0; ranks landing in the
    /// overflow region return the histogram's last bucket edge (the
    /// largest value it can resolve). An empty histogram returns 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total <= 0.0 {
            return 0;
        }
        let rank = q.clamp(0.0, 1.0) * self.total;
        let mut acc = self.zero;
        if rank <= acc {
            return 0;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            if c <= 0.0 {
                continue;
            }
            if rank <= acc + c {
                let lo = self.bucket_lo(i) as f64;
                let hi = self.bucket_lo(i + 1) as f64;
                let frac = (rank - acc) / c;
                return (lo + frac * (hi - lo).max(0.0)).round() as u64;
            }
            acc += c;
        }
        // The rank fell into the overflow region.
        self.bucket_lo(self.counts.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_assignment_and_cdf() {
        let mut h = LogHistogram::new(2.0, 1 << 20);
        h.inc(0);
        h.inc(1);
        h.inc(2);
        h.inc(3);
        h.inc(1024);
        assert_eq!(h.total(), 5.0);
        // values ≤ 1: zero bucket + bucket 0 (v=1)
        assert_eq!(h.cumulative_le(1), 2.0);
        // 2 and 3 share bucket 1
        assert_eq!(h.cumulative_le(3), 4.0);
        assert_eq!(h.cumulative_gt(3), 1.0);
        let cdf = h.cdf();
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn overflow_counted() {
        let mut h = LogHistogram::new(2.0, 16);
        h.inc(1 << 30);
        assert_eq!(h.overflow(), 1.0);
        assert_eq!(h.cumulative_gt(16), 1.0);
    }

    #[test]
    fn decay_and_clear() {
        let mut h = LogHistogram::new(2.0, 1024);
        for v in [1u64, 8, 64, 512] {
            h.add(v, 2.0);
        }
        h.decay(0.5);
        assert!((h.total() - 4.0).abs() < 1e-12);
        assert!((h.cumulative_le(1024) - 4.0).abs() < 1e-12);
        h.clear();
        assert_eq!(h.total(), 0.0);
    }

    #[test]
    fn quantile_edges() {
        let mut h = LogHistogram::new(2.0, 1 << 20);
        assert_eq!(h.quantile(0.5), 0, "empty histogram");
        h.inc(0);
        h.inc(0);
        assert_eq!(h.quantile(0.5), 0, "zero bucket absorbs the rank");
        let mut h = LogHistogram::new(2.0, 16);
        h.inc(1 << 30); // overflow
        assert_eq!(h.quantile(0.99), h.bucket_lo(h.num_buckets()));
        // A single mid-range value: every quantile lands in its bucket.
        let mut h = LogHistogram::new(2.0, 1 << 20);
        h.inc(1000);
        let v = h.quantile(0.5);
        assert!((512..=1024).contains(&v), "got {v}");
    }

    /// Property: against the exact percentile of the raw samples
    /// ([`crate::metrics::Summary::of`]), the interpolated histogram
    /// quantile is accurate to within one bucket (a factor of `base`).
    #[test]
    fn quantile_tracks_exact_percentiles() {
        let cases: usize = std::env::var("ELASTICTL_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(16);
        let mut rng = crate::util::rng::Pcg::seed_from_u64(0x0b5e);
        for case in 0..cases {
            let base = [1.1, 1.25, 1.5, 2.0][case % 4];
            let n = 200 + rng.below(2000) as usize;
            let mut h = LogHistogram::new(base, 1 << 30);
            let mut samples: Vec<f64> = Vec::with_capacity(n);
            for _ in 0..n {
                // Log-uniform magnitudes spanning six decades.
                let v = (10f64.powf(rng.f64() * 6.0)) as u64;
                h.inc(v);
                samples.push(v as f64);
            }
            let exact = crate::metrics::Summary::of(&samples).unwrap();
            for (q, want) in [(0.5, exact.p50), (0.9, exact.p90), (0.99, exact.p99)] {
                let got = h.quantile(q) as f64;
                // One bucket of resolution plus interpolation slack on
                // either side (the exact percentile uses nearest-rank,
                // the histogram interpolates).
                let tol = base * base;
                assert!(
                    got <= want * tol + 1.0 && got >= want / tol - 1.0,
                    "case {case}: base {base} q {q}: histogram {got} vs exact {want}"
                );
            }
        }
    }

    #[test]
    fn weighted_inserts() {
        let mut h = LogHistogram::new(1.5, 1 << 16);
        h.add(100, 10.0);
        h.add(100, 5.0);
        assert_eq!(h.total(), 15.0);
        assert_eq!(h.cumulative_le(200), 15.0);
    }
}
