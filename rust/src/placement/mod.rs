//! Physical placement subsystem: *where* a `(tenant, key)` lives and how
//! much resident memory each tenant actually holds.
//!
//! PR 3 made the arbiter's grants binding through an admission-rate
//! budget — an indirect bound: a cheap tenant's insert storm could still
//! physically evict a gold tenant's residents through shared-LRU
//! interference, exactly the cross-tenant contention Memshare (Cidon et
//! al., PAPERS.md) partitions away. This module closes the gap with two
//! halves:
//!
//! 1. **Physical occupancy accounting** — every store entry carries a
//!    tenant tag ([`crate::cache::Store::insert_tagged`]); evictions
//!    report `(tenant, bytes)` upward through an eviction sink; the
//!    [`crate::cluster::Cluster`] folds those events into a per-tenant
//!    resident-bytes ledger with the invariant
//!    `Σ per-tenant bytes == Cluster::used()`. Under
//!    `scaler.enforce_grants` the occupancy cap binds on *resident*
//!    bytes: over-cap tenants shed their own coldest entries at epoch
//!    boundaries ([`crate::cluster::Cluster::shed_tenant`]) instead of
//!    refusing admissions for repair traffic.
//!
//! 2. **A [`PlacementPolicy`]** deciding which instance a tenant's keys
//!    route to, selectable via the `[placement]` config section:
//!
//!    * [`PlacementKind::Shared`] — today's scoped-key hash-slot routing,
//!      the default, bit-identical to the pre-placement balancer (the
//!      engine-parity golden suite pins it).
//!    * [`PlacementKind::HashSlotPinned`] — each tenant is pinned to an
//!      instance subset sized from its grant, recomputed at epoch
//!      boundaries with minimal churn (existing pins are kept; a tenant
//!      squatting on a higher-priority tenant's instance migrates to a
//!      free one — the priority tenant keeps its warm residents; growth
//!      takes free instances first and refuses to overlap while the
//!      tenant has any pin).
//!    * [`PlacementKind::SlabPartition`] — Memshare-style per-tenant byte
//!      partitions *inside* each instance: reserved floors are honored
//!      (a tenant at or under its floor is protected from cross-tenant
//!      eviction), the pooled remainder stays evictable cross-tenant.
//!
//! The placement layer is deliberately passive on the request path: one
//! virtual `route` call per request, O(1) for every policy.

use crate::{ObjectId, Result, TenantId};

/// Which placement policy the cluster runs (`[placement] policy = "..."`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementKind {
    /// Scoped-key hash-slot routing over all instances (the default;
    /// bit-identical to the pre-placement cluster).
    #[default]
    Shared,
    /// Per-tenant instance subsets sized from the epoch grants.
    HashSlotPinned,
    /// Memshare-style per-tenant byte partitions inside each instance.
    SlabPartition,
}

impl PlacementKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PlacementKind::Shared => "shared",
            PlacementKind::HashSlotPinned => "hash_slot_pinned",
            PlacementKind::SlabPartition => "slab_partition",
        }
    }

    pub fn parse(s: &str) -> Result<PlacementKind> {
        Ok(match s {
            "shared" => PlacementKind::Shared,
            "hash_slot_pinned" | "hash-slot-pinned" | "pinned" => PlacementKind::HashSlotPinned,
            "slab_partition" | "slab-partition" | "partition" => PlacementKind::SlabPartition,
            other => anyhow::bail!(
                "unknown placement policy {other} (shared|hash_slot_pinned|slab_partition)"
            ),
        })
    }
}

/// One tenant's grant row as the placement layer sees it at an epoch
/// boundary (derived from [`crate::tenant::TenantEnforcement`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantGrant {
    pub tenant: TenantId,
    /// Bytes granted by the arbiter at the last epoch decision.
    pub granted_bytes: u64,
    /// Memshare-style reserved floor carried by the tenant's spec.
    pub reserved_bytes: u64,
}

/// Read-only snapshot of the placement state (the `PLACEMENT` serve
/// command renders this).
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementSnapshot {
    pub policy: PlacementKind,
    pub tenants: Vec<PlacementTenantRow>,
}

/// One tenant's row of a [`PlacementSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementTenantRow {
    pub tenant: TenantId,
    /// Physical resident bytes across the cluster (the ledger row).
    pub resident_bytes: u64,
    /// Instance subset the tenant is pinned to (`None` unless the
    /// placement policy pins).
    pub pins: Option<Vec<u32>>,
}

/// Strategy for placing `(tenant, key)` onto cluster instances.
///
/// `route` runs on the request path and must stay O(1); `on_grants` runs
/// once per epoch boundary and may do linear work in tenants × instances.
pub trait PlacementPolicy: Send {
    fn kind(&self) -> PlacementKind;

    /// Instance index for a request: `slot` is the object's hash slot,
    /// `shared_owner` the slot map's owner (the shared fallback), `n` the
    /// live instance count.
    fn route(&self, tenant: TenantId, slot: u32, shared_owner: usize, n: usize) -> usize;

    /// Epoch boundary: absorb the fresh grants (recompute pins or
    /// per-instance floors). `n` is the live instance count *after* the
    /// resize that precedes this call.
    fn on_grants(&mut self, grants: &[TenantGrant], n: usize, instance_bytes: u64);

    /// Per-tenant protected floors each instance must honor. `None`
    /// means the policy does not partition instances at all (stores are
    /// left untouched, keeping the default path bit-identical);
    /// `Some(&[])` means "partitioning is active but no floor is
    /// currently justified" and must be installed so stale floors from a
    /// previous epoch are cleared.
    fn instance_floors(&self) -> Option<&[(TenantId, u64)]> {
        None
    }

    /// Current instance pins for `tenant` (`None` unless the policy pins).
    fn pins(&self, tenant: TenantId) -> Option<&[u32]> {
        let _ = tenant;
        None
    }

    /// A tenant is retiring: release whatever the policy holds for it
    /// (pins, floors) so the drain can reclaim its residents and nothing
    /// stale survives into a later re-admission. Default: nothing held.
    fn release(&mut self, _tenant: TenantId) {}
}

/// Build the configured placement policy.
pub fn make_placement(kind: PlacementKind) -> Box<dyn PlacementPolicy> {
    match kind {
        PlacementKind::Shared => Box::new(SharedPlacement),
        PlacementKind::HashSlotPinned => Box::new(HashSlotPinned::new()),
        PlacementKind::SlabPartition => Box::new(SlabPartition::new()),
    }
}

/// Today's behavior: every tenant routes through the shared slot map.
pub struct SharedPlacement;

impl PlacementPolicy for SharedPlacement {
    fn kind(&self) -> PlacementKind {
        PlacementKind::Shared
    }

    #[inline]
    fn route(&self, _tenant: TenantId, _slot: u32, shared_owner: usize, _n: usize) -> usize {
        shared_owner
    }

    fn on_grants(&mut self, _grants: &[TenantGrant], _n: usize, _instance_bytes: u64) {}
}

/// Each tenant owns an instance subset sized from its grant
/// (`ceil(granted / S_p)`, clamped to `[1, n]`); its keys hash over that
/// subset only, so another tenant's insert storm cannot churn its
/// instances. Recomputation keeps existing pins (minimal churn), moves a
/// tenant found squatting on a higher-priority tenant's instance to a
/// free one when possible (the priority tenant's warm residents stay
/// put), and grows onto free instances only — a tenant never overlaps an
/// occupied instance while it holds at least one pin of its own.
pub struct HashSlotPinned {
    /// tenant id → pinned instance indices (empty = not pinned yet,
    /// routes shared).
    pins: Vec<Vec<u32>>,
}

impl HashSlotPinned {
    pub fn new() -> Self {
        HashSlotPinned { pins: Vec::new() }
    }

    fn pins_slot(&mut self, tenant: TenantId) -> &mut Vec<u32> {
        let id = tenant as usize;
        if self.pins.len() <= id {
            self.pins.resize_with(id + 1, Vec::new);
        }
        &mut self.pins[id]
    }
}

impl Default for HashSlotPinned {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementPolicy for HashSlotPinned {
    fn kind(&self) -> PlacementKind {
        PlacementKind::HashSlotPinned
    }

    #[inline]
    fn route(&self, tenant: TenantId, slot: u32, shared_owner: usize, n: usize) -> usize {
        match self.pins.get(tenant as usize) {
            Some(pins) if !pins.is_empty() => {
                let i = pins[slot as usize % pins.len()] as usize;
                if i < n {
                    i
                } else {
                    shared_owner
                }
            }
            // Unpinned tenants (pre-first-epoch, or strays the arbiter
            // has not granted yet) keep the shared routing.
            _ => shared_owner,
        }
    }

    fn on_grants(&mut self, grants: &[TenantGrant], n: usize, instance_bytes: u64) {
        if n == 0 || grants.is_empty() {
            return;
        }
        // Prune pins onto instances a shrink removed.
        for pins in &mut self.pins {
            pins.retain(|&i| (i as usize) < n);
        }
        // usage[i] = tenants currently pinned to instance i (all tenants,
        // stale ones included — their residents are still there).
        let mut usage = vec![0u32; n];
        for pins in &self.pins {
            for &i in pins {
                usage[i as usize] += 1;
            }
        }
        // Reservation-priority order: reserved desc, granted desc, id asc
        // — the squeeze (fewer pins than the grant justifies) lands on
        // the tenants with the weakest claims.
        let mut order: Vec<usize> = (0..grants.len()).collect();
        order.sort_by(|&a, &b| {
            grants[b]
                .reserved_bytes
                .cmp(&grants[a].reserved_bytes)
                .then(grants[b].granted_bytes.cmp(&grants[a].granted_bytes))
                .then(grants[a].tenant.cmp(&grants[b].tenant))
        });
        let s = instance_bytes.max(1);
        // Instances already claimed by a higher-priority tenant this
        // round: a later tenant found squatting on one migrates away (to
        // a free instance, if any) — the priority tenant keeps its warm
        // instances; the intruder eats the move.
        let mut claimed = vec![false; n];
        for gi in order {
            let g = &grants[gi];
            let k = (g.granted_bytes.div_ceil(s)).clamp(1, n as u64) as usize;
            let pins = self.pins_slot(g.tenant);
            // Shrink: drop the most recently added pins first.
            while pins.len() > k {
                let dropped = pins.pop().unwrap();
                usage[dropped as usize] -= 1;
            }
            // Migrate off instances a higher-priority tenant claimed.
            for slot in pins.iter_mut() {
                if claimed[*slot as usize] {
                    if let Some(free) = (0..n).find(|&j| usage[j] == 0) {
                        usage[*slot as usize] -= 1;
                        usage[free] += 1;
                        *slot = free as u32;
                    }
                }
            }
            // Grow onto free instances; never overlap while we own ≥ 1.
            while pins.len() < k {
                let mut best: Option<usize> = None;
                for j in 0..n {
                    if pins.contains(&(j as u32)) {
                        continue;
                    }
                    match best {
                        Some(b) if (usage[j], j) >= (usage[b], b) => {}
                        _ => best = Some(j),
                    }
                }
                let Some(j) = best else { break };
                if usage[j] > 0 && !pins.is_empty() {
                    break;
                }
                pins.push(j as u32);
                usage[j] += 1;
            }
            for &p in pins.iter() {
                claimed[p as usize] = true;
            }
        }
    }

    fn pins(&self, tenant: TenantId) -> Option<&[u32]> {
        self.pins.get(tenant as usize).map(|v| v.as_slice())
    }

    fn release(&mut self, tenant: TenantId) {
        if let Some(pins) = self.pins.get_mut(tenant as usize) {
            pins.clear();
        }
    }
}

/// Memshare-style partitions inside every instance: routing stays shared,
/// but each instance protects, per tenant, a byte floor
/// `min(reserved, granted) / n` (scaled down proportionally if the floors
/// alone oversubscribe the instance). A tenant at or under its floor is
/// immune to cross-tenant eviction; everything above the floors is the
/// pooled remainder, evictable by anyone in LRU order.
pub struct SlabPartition {
    /// Per-instance protected floors, recomputed each epoch.
    floors: Vec<(TenantId, u64)>,
}

impl SlabPartition {
    pub fn new() -> Self {
        SlabPartition { floors: Vec::new() }
    }
}

impl Default for SlabPartition {
    fn default() -> Self {
        Self::new()
    }
}

impl PlacementPolicy for SlabPartition {
    fn kind(&self) -> PlacementKind {
        PlacementKind::SlabPartition
    }

    #[inline]
    fn route(&self, _tenant: TenantId, _slot: u32, shared_owner: usize, _n: usize) -> usize {
        shared_owner
    }

    fn on_grants(&mut self, grants: &[TenantGrant], n: usize, instance_bytes: u64) {
        self.floors.clear();
        if n == 0 {
            return;
        }
        let n64 = n as u64;
        let raw: Vec<(TenantId, u64)> = grants
            .iter()
            .map(|g| (g.tenant, g.reserved_bytes.min(g.granted_bytes) / n64))
            .collect();
        // Keep Σ floors within ~90% of the instance so a pooled remainder
        // always exists (Memshare's pooled memory must not collapse to 0).
        let budget = instance_bytes - instance_bytes / 10;
        let total: u64 = raw.iter().map(|&(_, f)| f).sum();
        let scale = if total > budget && total > 0 {
            budget as f64 / total as f64
        } else {
            1.0
        };
        for (t, f) in raw {
            let f = (f as f64 * scale) as u64;
            if f > 0 {
                self.floors.push((t, f));
            }
        }
    }

    fn instance_floors(&self) -> Option<&[(TenantId, u64)]> {
        // Always `Some`, even when empty: an epoch whose grants justify
        // no floors must still clear the previous epoch's floors.
        Some(&self.floors)
    }

    fn release(&mut self, tenant: TenantId) {
        self.floors.retain(|&(t, _)| t != tenant);
    }
}

/// Fold a scoped object id to a hash slot — re-exported convenience for
/// standalone placement tests (mirrors `Cluster::slot_of`).
#[inline]
pub fn slot_of(obj: ObjectId, hash_slots: u32) -> u32 {
    (crate::mix64(obj) % hash_slots as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grants(rows: &[(u16, u64, u64)]) -> Vec<TenantGrant> {
        rows.iter()
            .map(|&(tenant, granted_bytes, reserved_bytes)| TenantGrant {
                tenant,
                granted_bytes,
                reserved_bytes,
            })
            .collect()
    }

    #[test]
    fn kind_round_trip() {
        for k in [
            PlacementKind::Shared,
            PlacementKind::HashSlotPinned,
            PlacementKind::SlabPartition,
        ] {
            assert_eq!(PlacementKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(PlacementKind::parse("nope").is_err());
        assert_eq!(PlacementKind::default(), PlacementKind::Shared);
    }

    #[test]
    fn shared_routes_to_slot_owner() {
        let p = make_placement(PlacementKind::Shared);
        assert_eq!(p.kind(), PlacementKind::Shared);
        for slot in 0..100u32 {
            assert_eq!(p.route(3, slot, 7, 8), 7);
        }
        assert!(p.instance_floors().is_none());
        assert!(p.pins(0).is_none());
    }

    #[test]
    fn pinned_sizes_subsets_from_grants_without_overlap() {
        let mut p = HashSlotPinned::new();
        let s = 100u64;
        // gold: 3 instances worth; flood: wants 4 but only 3 stay free.
        p.on_grants(&grants(&[(0, 300, 300), (1, 400, 100)]), 6, s);
        let gold: Vec<u32> = p.pins(0).unwrap().to_vec();
        let flood: Vec<u32> = p.pins(1).unwrap().to_vec();
        assert_eq!(gold.len(), 3, "{gold:?}");
        assert_eq!(flood.len(), 3, "{flood:?}");
        assert!(gold.iter().all(|i| !flood.contains(i)), "{gold:?} vs {flood:?}");
        // Routing stays inside the pinned subset, deterministically.
        for slot in 0..1000u32 {
            let r = p.route(0, slot, 5, 6) as u32;
            assert!(gold.contains(&r), "slot {slot} routed to {r}");
            assert_eq!(r as usize, p.route(0, slot, 5, 6));
        }
        // Unpinned strays keep the shared owner.
        assert_eq!(p.route(9, 42, 5, 6), 5);
    }

    #[test]
    fn pinned_recompute_has_minimal_churn() {
        let mut p = HashSlotPinned::new();
        let s = 100u64;
        p.on_grants(&grants(&[(0, 300, 300)]), 6, s);
        let before: Vec<u32> = p.pins(0).unwrap().to_vec();
        // Same grants → identical pins.
        p.on_grants(&grants(&[(0, 300, 300)]), 6, s);
        assert_eq!(p.pins(0).unwrap(), &before[..]);
        // Growth keeps the old pins as a prefix.
        p.on_grants(&grants(&[(0, 500, 300)]), 6, s);
        let grown = p.pins(0).unwrap();
        assert_eq!(&grown[..3], &before[..]);
        assert_eq!(grown.len(), 5);
        // Shrink drops the most recently added pins.
        p.on_grants(&grants(&[(0, 200, 200)]), 6, s);
        assert_eq!(p.pins(0).unwrap(), &before[..2]);
    }

    #[test]
    fn pinned_migration_moves_the_intruder_not_the_priority_tenant() {
        let mut p = HashSlotPinned::new();
        let s = 100u64;
        // n=2, no free instance: gold takes both, the flood squats on one
        // (unavoidable overlap — a pinless tenant takes the least-used).
        p.on_grants(&grants(&[(0, 200, 200), (1, 100, 50)]), 2, s);
        let gold: Vec<u32> = p.pins(0).unwrap().to_vec();
        assert_eq!(gold.len(), 2);
        assert_eq!(p.pins(1).unwrap().len(), 1);
        // The cluster grows: the *flood* must migrate to the fresh
        // instance — the gold tenant keeps its warm residents in place.
        p.on_grants(&grants(&[(0, 200, 200), (1, 100, 50)]), 4, s);
        assert_eq!(p.pins(0).unwrap(), &gold[..], "gold keeps its warm instances");
        let flood = p.pins(1).unwrap();
        assert_eq!(flood.len(), 1);
        assert!(!gold.contains(&flood[0]), "the intruder migrated off gold: {flood:?}");
    }

    #[test]
    fn pinned_prunes_after_cluster_shrink() {
        let mut p = HashSlotPinned::new();
        p.on_grants(&grants(&[(0, 600, 600)]), 6, 100);
        assert_eq!(p.pins(0).unwrap().len(), 6);
        // The cluster shrank to 2 instances: stale pins must go, and the
        // route must never leave the live range.
        p.on_grants(&grants(&[(0, 600, 600)]), 2, 100);
        let pins = p.pins(0).unwrap();
        assert_eq!(pins.len(), 2);
        assert!(pins.iter().all(|&i| i < 2));
        for slot in 0..100u32 {
            assert!(p.route(0, slot, 0, 2) < 2);
        }
    }

    #[test]
    fn partition_floors_honor_reservations_and_leave_pool() {
        let mut p = SlabPartition::new();
        // Routing is shared.
        assert_eq!(p.route(1, 9, 4, 6), 4);
        p.on_grants(&grants(&[(0, 600, 300), (1, 600, 0)]), 3, 1000);
        let floors = p.instance_floors().unwrap();
        // floor = min(reserved, granted)/n; unreserved tenants get none.
        assert_eq!(floors, &[(0, 100)]);
        // Oversubscribed floors scale down to leave a pooled remainder.
        let mut p = SlabPartition::new();
        p.on_grants(&grants(&[(0, 3000, 3000), (1, 3000, 3000)]), 1, 1000);
        let floors = p.instance_floors().unwrap();
        let total: u64 = floors.iter().map(|&(_, f)| f).sum();
        assert!(total <= 900, "floors {floors:?} must leave ≥10% pooled");
        assert_eq!(floors.len(), 2);
        // No grants → an *empty* floor set (still Some: stale floors from
        // the previous epoch must be cleared, not left in force).
        p.on_grants(&grants(&[]), 3, 1000);
        assert!(p.instance_floors().unwrap().is_empty());
    }
}
