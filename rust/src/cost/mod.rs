//! Cost accounting (§2.3): storage cost `C^s(1,k) = Σ_h c^s·I(h)` billed
//! per epoch, miss cost `C^m = Σ_n m_{r(n)}` accrued per miss, and the
//! per-run cumulative series of Figs. 6–8.
//!
//! Multi-tenant runs additionally keep one [`TenantLedger`] per tenant:
//! misses are billed at `weight_t × m_o` (the tenant's miss-cost
//! multiplier) and attributed to the requesting tenant, and each epoch's
//! storage bill is **attributed** across tenants in proportion to their
//! physical resident bytes at the boundary ([`TenantEpochBill`]), so
//! fig10/fig13 can report who spent what on the shared cluster.
//!
//! The attribution is **exact by construction**: the cluster's running
//! totals are accumulated as the very same fold (epoch-major, tenant id
//! ascending within each epoch) over the per-tenant bills that
//! [`CostTracker::tenant_bills`] records, so
//! `Σ per-epoch tenant bills == total cluster bill` holds bit-for-bit,
//! not merely to within floating-point tolerance — the invariant the
//! `tenant_churn` property suite pins even with tenants admitted and
//! retired mid-run. Retiring a tenant closes its ledger through
//! [`CostTracker::close_tenant`], which snapshots the final
//! [`TenantReconciliation`].

use crate::config::CostConfig;
use crate::metrics::TimeSeries;
use crate::{TenantId, TimeUs};

/// Per-tenant slice of the bill: misses attributed per request, storage
/// attributed per epoch in proportion to resident bytes.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TenantLedger {
    /// Cumulative misses by this tenant.
    pub misses: u64,
    /// Cumulative weighted miss dollars (closed epochs + the open one).
    pub miss_dollars: f64,
    /// Cumulative storage dollars attributed at epoch boundaries.
    pub storage_dollars: f64,
}

impl TenantLedger {
    /// The tenant's total bill so far.
    pub fn total_dollars(&self) -> f64 {
        self.storage_dollars + self.miss_dollars
    }
}

/// One tenant's slice of one closed epoch's bill. The stream of these
/// rows (epoch-major, tenant id ascending) *is* the cluster bill: the
/// tracker's totals are accumulated as the fold over exactly these
/// values, so their sum reproduces the total bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantEpochBill {
    /// Epoch-close timestamp.
    pub t: TimeUs,
    /// The billed tenant.
    pub tenant: TenantId,
    /// Storage dollars attributed for the epoch (∝ resident bytes).
    pub storage: f64,
    /// Weighted miss dollars this tenant accrued within the epoch.
    pub miss: f64,
}

/// Final bill of a retired tenant, snapshotted by
/// [`CostTracker::close_tenant`] once its residents are fully drained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantReconciliation {
    /// The retired tenant.
    pub tenant: TenantId,
    /// Time of the reconciliation (the drain-completion boundary).
    pub at: TimeUs,
    /// Lifetime misses.
    pub misses: u64,
    /// Lifetime weighted miss dollars.
    pub miss_dollars: f64,
    /// Lifetime attributed storage dollars.
    pub storage_dollars: f64,
    /// The closed bill: `storage_dollars + miss_dollars`, exactly the
    /// fold of the tenant's [`TenantEpochBill`] rows.
    pub total_dollars: f64,
}

/// Running cost ledger for one policy run.
#[derive(Debug)]
pub struct CostTracker {
    cfg: CostConfig,
    /// Total storage dollars so far.
    storage_total: f64,
    /// Total miss dollars so far.
    miss_total: f64,
    /// Miss dollars accrued within the current epoch.
    epoch_miss: f64,
    /// Misses within the current epoch.
    epoch_miss_count: u64,
    /// Per-tenant miss dollars accrued within the *open* epoch, indexed
    /// by tenant id. Folded into the ledgers (and the cluster totals — the
    /// same fold, so the attribution stays exact) at each epoch close.
    epoch_tenant_miss: Vec<f64>,
    /// Per-tenant attribution of closed epochs, indexed by tenant id
    /// (grown on demand; single-tenant runs only ever touch slot 0).
    tenant_ledgers: Vec<TenantLedger>,
    /// Per-tenant miss-cost multipliers, indexed by tenant id (missing =
    /// 1.0).
    tenant_weights: Vec<f64>,
    /// Every per-tenant epoch bill, in accumulation order (epoch-major,
    /// tenant id ascending) — folding these reproduces the totals
    /// bit-for-bit.
    tenant_bills: Vec<TenantEpochBill>,
    /// Closed bills of retired tenants.
    reconciliations: Vec<TenantReconciliation>,
    /// Cumulative series sampled at epoch boundaries.
    pub storage_series: TimeSeries,
    pub miss_series: TimeSeries,
    pub total_series: TimeSeries,
    /// Instances billed per epoch.
    pub instances_series: TimeSeries,
    epochs: u64,
}

impl CostTracker {
    pub fn new(cfg: CostConfig) -> Self {
        CostTracker {
            cfg,
            storage_total: 0.0,
            miss_total: 0.0,
            epoch_miss: 0.0,
            epoch_miss_count: 0,
            epoch_tenant_miss: Vec::new(),
            tenant_ledgers: Vec::new(),
            tenant_weights: Vec::new(),
            tenant_bills: Vec::new(),
            reconciliations: Vec::new(),
            storage_series: TimeSeries::new("storage_cum"),
            miss_series: TimeSeries::new("miss_cum"),
            total_series: TimeSeries::new("total_cum"),
            instances_series: TimeSeries::new("instances"),
            epochs: 0,
        }
    }

    pub fn config(&self) -> &CostConfig {
        &self.cfg
    }

    /// Set tenant `t`'s miss-cost multiplier (default 1.0).
    pub fn set_tenant_weight(&mut self, t: TenantId, weight: f64) {
        let i = t as usize;
        if self.tenant_weights.len() <= i {
            self.tenant_weights.resize(i + 1, 1.0);
        }
        self.tenant_weights[i] = weight;
    }

    /// Miss-cost multiplier for tenant `t`.
    #[inline]
    pub fn tenant_weight(&self, t: TenantId) -> f64 {
        self.tenant_weights.get(t as usize).copied().unwrap_or(1.0)
    }

    /// Tenant `t`'s cumulative attribution (zero if never seen). Includes
    /// the open epoch's miss accruals, so mid-run reads stay current.
    pub fn tenant_ledger(&self, t: TenantId) -> TenantLedger {
        let mut ledger = self
            .tenant_ledgers
            .get(t as usize)
            .copied()
            .unwrap_or_default();
        ledger.miss_dollars += self.epoch_tenant_miss.get(t as usize).copied().unwrap_or(0.0);
        ledger
    }

    /// All per-tenant ledgers (closed epochs only), indexed by tenant id.
    pub fn tenant_ledgers(&self) -> &[TenantLedger] {
        &self.tenant_ledgers
    }

    /// Every per-tenant epoch bill so far, in accumulation order
    /// (epoch-major, tenant id ascending within an epoch). Folding the
    /// `storage` and `miss` fields in this order reproduces
    /// [`Self::storage_total`] / the closed-epoch miss total bit-for-bit.
    pub fn tenant_bills(&self) -> &[TenantEpochBill] {
        &self.tenant_bills
    }

    /// Closed bills of retired tenants, in retirement order.
    pub fn reconciliations(&self) -> &[TenantReconciliation] {
        &self.reconciliations
    }

    /// Record one miss for an object of `size` bytes (tenant 0).
    #[inline]
    pub fn record_miss(&mut self, size: u64) {
        self.record_miss_for(0, size);
    }

    /// Record one miss by tenant `t` for an object of `size` bytes,
    /// billed at the tenant's weighted miss cost.
    #[inline]
    pub fn record_miss_for(&mut self, t: TenantId, size: u64) {
        let m = self.cfg.miss_cost(size) * self.tenant_weight(t);
        self.epoch_miss += m;
        self.epoch_miss_count += 1;
        let i = t as usize;
        if self.tenant_ledgers.len() <= i {
            self.tenant_ledgers.resize(i + 1, TenantLedger::default());
        }
        if self.epoch_tenant_miss.len() <= i {
            self.epoch_tenant_miss.resize(i + 1, 0.0);
        }
        self.tenant_ledgers[i].misses += 1;
        self.epoch_tenant_miss[i] += m;
    }

    /// Replay a coalesced run of `count` identical miss charges of
    /// `dollars` each for tenant `t` — the shard-merge path
    /// (`engine::ShardedEngine`) folds per-shard miss ledgers back into
    /// the front tracker with this. The fold is performed addend by
    /// addend, in the same `+=` order the monolithic engine would have
    /// used, so a run replay is bit-identical to `count` calls of
    /// [`Self::record_miss_for`] with the same per-miss dollars.
    pub fn record_miss_dollars_run(&mut self, t: TenantId, dollars: f64, count: u64) {
        let i = t as usize;
        if self.tenant_ledgers.len() <= i {
            self.tenant_ledgers.resize(i + 1, TenantLedger::default());
        }
        if self.epoch_tenant_miss.len() <= i {
            self.epoch_tenant_miss.resize(i + 1, 0.0);
        }
        self.epoch_miss_count += count;
        self.tenant_ledgers[i].misses += count;
        for _ in 0..count {
            self.epoch_miss += dollars;
            self.epoch_tenant_miss[i] += dollars;
        }
    }

    /// Record an arbitrary storage charge (used by the ideal TTL cache,
    /// billed on instantaneous occupancy rather than per instance).
    #[inline]
    pub fn record_storage_dollars(&mut self, dollars: f64) {
        self.storage_total += dollars;
    }

    /// Close the epoch that just ended at `t`, billing `instances` nodes
    /// for the whole epoch (§2.3: turning a node off early is paid
    /// anyway). Equivalent to [`Self::end_epoch_attributed`] with no
    /// resident information: the whole epoch bill lands on tenant 0.
    pub fn end_epoch(&mut self, t: TimeUs, instances: u32) -> EpochCosts {
        self.end_epoch_attributed(t, instances, &[])
    }

    /// Close the epoch that just ended at `t`, billing `instances` nodes
    /// for the whole epoch and attributing the storage bill across
    /// tenants in proportion to `residents` (each tenant's physical
    /// resident bytes — the cluster placement ledger rows at the
    /// boundary). The per-tenant rows are appended to
    /// [`Self::tenant_bills`] and the cluster totals are accumulated as
    /// the fold over those very rows, keeping
    /// `Σ tenant bills == total bill` exact. With no residents (an empty
    /// cluster, or a tenant-oblivious caller) the storage lands on
    /// tenant 0, which keeps single-tenant runs bit-identical with the
    /// unattributed accounting.
    pub fn end_epoch_attributed(
        &mut self,
        t: TimeUs,
        instances: u32,
        residents: &[(TenantId, u64)],
    ) -> EpochCosts {
        let storage = instances as f64 * self.cfg.instance.dollars_per_hour
            * (self.cfg.epoch_us as f64 / crate::HOUR as f64);
        let out = self.close_epoch_bills(t, Some((storage, residents)), instances);
        self.instances_series.push(t, instances as f64);
        out
    }

    /// Close an epoch for a vertically billed (ideal TTL) run: storage was
    /// already accrued via [`Self::record_storage_dollars`] and stays
    /// unattributed; only the misses land on tenant bills.
    pub fn end_epoch_vertical(&mut self, t: TimeUs) -> EpochCosts {
        self.close_epoch_bills(t, None, 0)
    }

    /// Shared epoch-close: emit the per-tenant bill rows (tenant id
    /// ascending), fold them into the ledgers and the cluster totals, and
    /// reset the per-epoch accruals.
    fn close_epoch_bills(
        &mut self,
        t: TimeUs,
        storage: Option<(f64, &[(TenantId, u64)])>,
        instances: u32,
    ) -> EpochCosts {
        // Per-tenant storage shares, resident-byte proportional. The last
        // share-holder takes the residual so the rows fold back to the
        // exact epoch storage bill.
        let mut shares: Vec<(TenantId, f64)> = Vec::new();
        let mut epoch_storage = 0.0;
        if let Some((storage, residents)) = storage {
            let mut rows: Vec<(TenantId, u64)> = residents
                .iter()
                .copied()
                .filter(|&(_, b)| b > 0)
                .collect();
            rows.sort_by_key(|&(t, _)| t);
            let total_resident: u64 = rows.iter().map(|&(_, b)| b).sum();
            if total_resident == 0 {
                shares.push((0, storage));
            } else {
                let mut allotted = 0.0;
                for (i, &(tenant, bytes)) in rows.iter().enumerate() {
                    let s = if i + 1 == rows.len() {
                        storage - allotted
                    } else {
                        storage * (bytes as f64 / total_resident as f64)
                    };
                    allotted += s;
                    shares.push((tenant, s));
                }
            }
        }
        // One pass over every tenant touched this epoch, id ascending:
        // emit the bill row and fold it into ledger + totals.
        let mut epoch_miss = 0.0;
        let max_len = self
            .epoch_tenant_miss
            .len()
            .max(shares.iter().map(|&(t, _)| t as usize + 1).max().unwrap_or(0));
        if self.tenant_ledgers.len() < max_len {
            self.tenant_ledgers.resize(max_len, TenantLedger::default());
        }
        let mut share_iter = shares.iter().peekable();
        for id in 0..max_len {
            let s = match share_iter.peek() {
                Some(&&(tenant, s)) if tenant as usize == id => {
                    share_iter.next();
                    s
                }
                _ => 0.0,
            };
            let m = self.epoch_tenant_miss.get(id).copied().unwrap_or(0.0);
            if s == 0.0 && m == 0.0 {
                continue;
            }
            self.tenant_ledgers[id].storage_dollars += s;
            self.tenant_ledgers[id].miss_dollars += m;
            epoch_storage += s;
            epoch_miss += m;
            self.tenant_bills.push(TenantEpochBill {
                t,
                tenant: id as TenantId,
                storage: s,
                miss: m,
            });
        }
        self.storage_total += epoch_storage;
        self.miss_total += epoch_miss;
        let out = EpochCosts {
            t,
            storage: epoch_storage,
            miss: epoch_miss,
            miss_count: self.epoch_miss_count,
            instances,
        };
        self.epoch_miss = 0.0;
        self.epoch_miss_count = 0;
        self.epoch_tenant_miss.fill(0.0);
        self.epochs += 1;
        self.storage_series.push(t, self.storage_total);
        self.miss_series.push(t, self.miss_total);
        self.total_series.push(t, self.total());
        out
    }

    /// Close a retired tenant's ledger: snapshot its lifetime bill as a
    /// [`TenantReconciliation`]. Called by the engine once the tenant's
    /// residents are fully drained (so the final epoch it occupied
    /// anything has been billed). The ledger itself keeps accumulating if
    /// the retired tenant somehow sends more traffic; the reconciliation
    /// is the bill at close time.
    pub fn close_tenant(&mut self, t: TenantId, at: TimeUs) -> TenantReconciliation {
        let ledger = self.tenant_ledger(t);
        let rec = TenantReconciliation {
            tenant: t,
            at,
            misses: ledger.misses,
            miss_dollars: ledger.miss_dollars,
            storage_dollars: ledger.storage_dollars,
            total_dollars: ledger.storage_dollars + ledger.miss_dollars,
        };
        self.reconciliations.push(rec);
        rec
    }

    /// Restore the tracker to the state a crashed run checkpointed at its
    /// last closed epoch (server resume — see `srv::checkpoint`): replay
    /// the closed [`EpochCosts`] rows into the totals **as the same
    /// epoch-major fold the live path used** (so the restored cumulative
    /// bills are bit-identical, not merely close), re-append the
    /// [`TenantEpochBill`] / [`TenantReconciliation`] rows, and install
    /// the per-tenant cumulative ledger snapshots. Call on a fresh
    /// tracker only, before any traffic.
    pub fn restore_closed_epochs(
        &mut self,
        epochs: &[EpochCosts],
        bills: &[TenantEpochBill],
        reconciliations: &[TenantReconciliation],
        ledgers: &[(TenantId, TenantLedger)],
    ) {
        for e in epochs {
            self.storage_total += e.storage;
            self.miss_total += e.miss;
            self.epochs += 1;
            self.storage_series.push(e.t, self.storage_total);
            self.miss_series.push(e.t, self.miss_total);
            self.total_series.push(e.t, self.total());
            self.instances_series.push(e.t, e.instances as f64);
        }
        self.tenant_bills.extend_from_slice(bills);
        self.reconciliations.extend_from_slice(reconciliations);
        for &(t, l) in ledgers {
            let i = t as usize;
            if self.tenant_ledgers.len() <= i {
                self.tenant_ledgers.resize(i + 1, TenantLedger::default());
            }
            self.tenant_ledgers[i] = l;
        }
    }

    pub fn storage_total(&self) -> f64 {
        self.storage_total
    }

    pub fn miss_total(&self) -> f64 {
        // Include the open epoch so totals are usable mid-run.
        self.miss_total + self.epoch_miss
    }

    pub fn total(&self) -> f64 {
        self.storage_total + self.miss_total()
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }
}

/// The miss-billing sink the balancer charges on every physical miss.
/// The monolithic engine hands the balancer the [`CostTracker`] itself;
/// a shard worker hands it a local ledger that coalesces misses into
/// `(tenant, dollars, count)` runs for exact replay at the epoch barrier
/// (`engine::ShardedEngine`).
pub trait MissAccountant {
    /// Charge tenant `t` for one miss of an object of `size_bytes`.
    fn record_miss_for(&mut self, t: TenantId, size_bytes: u64);
}

impl MissAccountant for CostTracker {
    #[inline]
    fn record_miss_for(&mut self, t: TenantId, size_bytes: u64) {
        CostTracker::record_miss_for(self, t, size_bytes);
    }
}

/// Costs attributed to one closed epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochCosts {
    pub t: TimeUs,
    pub storage: f64,
    pub miss: f64,
    pub miss_count: u64,
    pub instances: u32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostConfig;
    use crate::HOUR;

    #[test]
    fn storage_bills_per_instance_hour() {
        let mut t = CostTracker::new(CostConfig::default());
        let e = t.end_epoch(HOUR, 8);
        assert!((e.storage - 8.0 * 0.017).abs() < 1e-12);
        assert_eq!(e.instances, 8);
        assert!((t.total() - 0.136).abs() < 1e-9);
    }

    #[test]
    fn miss_costs_accumulate_per_epoch() {
        let mut t = CostTracker::new(CostConfig::default());
        for _ in 0..1000 {
            t.record_miss(4096);
        }
        let e = t.end_epoch(HOUR, 1);
        assert_eq!(e.miss_count, 1000);
        assert!((e.miss - 1000.0 * 1.4676e-7).abs() < 1e-12);
        // epoch counters reset
        let e2 = t.end_epoch(2 * HOUR, 1);
        assert_eq!(e2.miss_count, 0);
        assert_eq!(e2.miss, 0.0);
    }

    #[test]
    fn series_are_cumulative_and_aligned() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        t.end_epoch(HOUR, 2);
        t.record_miss(1);
        t.record_miss(1);
        t.end_epoch(2 * HOUR, 3);
        assert_eq!(t.storage_series.len(), 2);
        let (_, s2) = t.storage_series.last().unwrap();
        assert!((s2 - 5.0 * 0.017).abs() < 1e-12);
        let (_, m2) = t.miss_series.last().unwrap();
        assert!((m2 - 3.0 * 1.4676e-7).abs() < 1e-15);
        let (_, tot) = t.total_series.last().unwrap();
        assert!((tot - (s2 + m2)).abs() < 1e-12);
        assert_eq!(t.epochs(), 2);
    }

    #[test]
    fn vertical_billing_accrues_directly() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_storage_dollars(0.5);
        t.record_miss(1);
        let e = t.end_epoch_vertical(HOUR);
        assert_eq!(e.storage, 0.0); // storage accrued out of band
        assert!((t.storage_total() - 0.5).abs() < 1e-12);
        assert!(t.total() > 0.5);
    }

    #[test]
    fn open_epoch_included_in_running_totals() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        assert!(t.miss_total() > 0.0);
        assert_eq!(t.total(), t.miss_total());
    }

    #[test]
    fn attributed_epochs_fold_back_to_the_exact_totals() {
        let mut t = CostTracker::new(CostConfig::default());
        t.set_tenant_weight(1, 3.0);
        t.set_tenant_weight(2, 0.5);
        // Epoch 1: two tenants resident, both missing.
        t.record_miss_for(1, 4096);
        t.record_miss_for(2, 4096);
        t.end_epoch_attributed(HOUR, 4, &[(1, 300), (2, 100)]);
        // Epoch 2: tenant 2 drained away mid-run; tenant 7 showed up.
        t.record_miss_for(7, 4096);
        t.end_epoch_attributed(2 * HOUR, 3, &[(1, 500), (7, 250)]);
        // Epoch 3: idle cluster — the bill lands on tenant 0.
        t.end_epoch_attributed(3 * HOUR, 2, &[]);

        // The bill rows fold back to the totals bit-for-bit.
        let (mut s, mut m) = (0.0, 0.0);
        let mut per_epoch: std::collections::BTreeMap<u64, (f64, f64)> = Default::default();
        for b in t.tenant_bills() {
            let e = per_epoch.entry(b.t).or_insert((0.0, 0.0));
            e.0 += b.storage;
            e.1 += b.miss;
        }
        for (_, (se, me)) in per_epoch {
            s += se;
            m += me;
        }
        assert_eq!(s, t.storage_total(), "storage fold must be exact");
        assert_eq!(m, t.miss_total(), "miss fold must be exact");
        assert_eq!(s + m, t.total(), "total fold must be exact");
        // Storage shares follow resident bytes; the idle epoch billed
        // tenant 0.
        let e1: Vec<_> = t.tenant_bills().iter().filter(|b| b.t == HOUR).collect();
        assert_eq!(e1.len(), 2);
        assert!(e1[0].tenant == 1 && e1[1].tenant == 2);
        assert!(e1[0].storage > 2.9 * e1[1].storage, "{e1:?}");
        let idle: Vec<_> = t.tenant_bills().iter().filter(|b| b.t == 3 * HOUR).collect();
        assert_eq!(idle.len(), 1);
        assert_eq!(idle[0].tenant, 0);
        assert_eq!(idle[0].miss, 0.0);

        // close_tenant snapshots the ledger as the reconciliation.
        let rec = t.close_tenant(2, 3 * HOUR);
        let bills_2: Vec<_> = t.tenant_bills().iter().filter(|b| b.tenant == 2).collect();
        let (mut s2, mut m2) = (0.0, 0.0);
        for b in &bills_2 {
            s2 += b.storage;
            m2 += b.miss;
        }
        assert_eq!(rec.storage_dollars, s2, "per-tenant storage fold must be exact");
        assert_eq!(rec.miss_dollars, m2, "per-tenant miss fold must be exact");
        assert_eq!(rec.total_dollars, s2 + m2);
        assert_eq!(rec.misses, 1);
        assert_eq!(t.reconciliations().len(), 1);
    }

    #[test]
    fn restore_replays_closed_epochs_bit_identically() {
        // Run A: two attributed epochs with weighted tenants, one retirement.
        let mut a = CostTracker::new(CostConfig::default());
        a.set_tenant_weight(1, 3.0);
        a.record_miss_for(1, 4096);
        a.record_miss_for(2, 4096);
        let e1 = a.end_epoch_attributed(HOUR, 4, &[(1, 300), (2, 100)]);
        a.record_miss_for(7, 4096);
        let e2 = a.end_epoch_attributed(2 * HOUR, 3, &[(1, 500), (7, 250)]);
        let rec = a.close_tenant(2, 2 * HOUR);

        // Run B: a fresh tracker restored from A's checkpointed state.
        let mut b = CostTracker::new(CostConfig::default());
        b.set_tenant_weight(1, 3.0);
        let ledgers: Vec<(TenantId, TenantLedger)> = a
            .tenant_ledgers()
            .iter()
            .enumerate()
            .map(|(i, &l)| (i as TenantId, l))
            .collect();
        b.restore_closed_epochs(&[e1, e2], a.tenant_bills(), &[rec], &ledgers);

        assert_eq!(b.epochs(), a.epochs());
        assert_eq!(b.storage_total(), a.storage_total(), "bit-identical storage");
        assert_eq!(b.miss_total(), a.miss_total(), "bit-identical miss dollars");
        assert_eq!(b.tenant_bills(), a.tenant_bills());
        assert_eq!(b.reconciliations(), a.reconciliations());
        assert_eq!(b.tenant_ledgers(), a.tenant_ledgers());

        // New epochs continue the fold exactly as the uninterrupted run.
        let mut c = a;
        c.record_miss_for(1, 4096);
        b.record_miss_for(1, 4096);
        assert_eq!(
            b.end_epoch_attributed(3 * HOUR, 3, &[(1, 200)]),
            c.end_epoch_attributed(3 * HOUR, 3, &[(1, 200)]),
        );
        assert_eq!(b.total(), c.total());
        assert_eq!(b.tenant_bills(), c.tenant_bills());
    }

    #[test]
    fn tenant_ledgers_attribute_weighted_misses() {
        let mut t = CostTracker::new(CostConfig::default());
        let m = t.config().miss_cost_dollars;
        t.set_tenant_weight(1, 3.0);
        t.set_tenant_weight(2, 0.5);
        t.record_miss_for(1, 4096);
        t.record_miss_for(1, 4096);
        t.record_miss_for(2, 4096);
        t.record_miss(4096); // tenant 0, weight 1.0
        let l0 = t.tenant_ledger(0);
        let l1 = t.tenant_ledger(1);
        let l2 = t.tenant_ledger(2);
        assert_eq!((l0.misses, l1.misses, l2.misses), (1, 2, 1));
        assert!((l1.miss_dollars - 2.0 * 3.0 * m).abs() < 1e-15);
        assert!((l2.miss_dollars - 0.5 * m).abs() < 1e-15);
        assert!((l0.miss_dollars - m).abs() < 1e-15);
        // The aggregate bill is the sum of the ledgers.
        let sum = l0.miss_dollars + l1.miss_dollars + l2.miss_dollars;
        assert!((t.miss_total() - sum).abs() < 1e-15);
        // Unknown tenants read as zero / weight 1.
        assert_eq!(t.tenant_ledger(40), TenantLedger::default());
        assert_eq!(t.tenant_weight(40), 1.0);
    }
}
