//! Cost accounting (§2.3): storage cost `C^s(1,k) = Σ_h c^s·I(h)` billed
//! per epoch, miss cost `C^m = Σ_n m_{r(n)}` accrued per miss, and the
//! per-run cumulative series of Figs. 6–8.

use crate::config::CostConfig;
use crate::metrics::TimeSeries;
use crate::TimeUs;

/// Running cost ledger for one policy run.
#[derive(Debug)]
pub struct CostTracker {
    cfg: CostConfig,
    /// Total storage dollars so far.
    storage_total: f64,
    /// Total miss dollars so far.
    miss_total: f64,
    /// Miss dollars accrued within the current epoch.
    epoch_miss: f64,
    /// Misses within the current epoch.
    epoch_miss_count: u64,
    /// Cumulative series sampled at epoch boundaries.
    pub storage_series: TimeSeries,
    pub miss_series: TimeSeries,
    pub total_series: TimeSeries,
    /// Instances billed per epoch.
    pub instances_series: TimeSeries,
    epochs: u64,
}

impl CostTracker {
    pub fn new(cfg: CostConfig) -> Self {
        CostTracker {
            cfg,
            storage_total: 0.0,
            miss_total: 0.0,
            epoch_miss: 0.0,
            epoch_miss_count: 0,
            storage_series: TimeSeries::new("storage_cum"),
            miss_series: TimeSeries::new("miss_cum"),
            total_series: TimeSeries::new("total_cum"),
            instances_series: TimeSeries::new("instances"),
            epochs: 0,
        }
    }

    pub fn config(&self) -> &CostConfig {
        &self.cfg
    }

    /// Record one miss for an object of `size` bytes.
    #[inline]
    pub fn record_miss(&mut self, size: u64) {
        let m = self.cfg.miss_cost(size);
        self.epoch_miss += m;
        self.epoch_miss_count += 1;
    }

    /// Record an arbitrary storage charge (used by the ideal TTL cache,
    /// billed on instantaneous occupancy rather than per instance).
    #[inline]
    pub fn record_storage_dollars(&mut self, dollars: f64) {
        self.storage_total += dollars;
    }

    /// Close the epoch that just ended at `t`, billing `instances` nodes
    /// for the whole epoch (§2.3: turning a node off early is paid anyway).
    pub fn end_epoch(&mut self, t: TimeUs, instances: u32) -> EpochCosts {
        let storage = instances as f64 * self.cfg.instance.dollars_per_hour
            * (self.cfg.epoch_us as f64 / crate::HOUR as f64);
        self.storage_total += storage;
        self.miss_total += self.epoch_miss;
        let out = EpochCosts {
            t,
            storage,
            miss: self.epoch_miss,
            miss_count: self.epoch_miss_count,
            instances,
        };
        self.epoch_miss = 0.0;
        self.epoch_miss_count = 0;
        self.epochs += 1;
        self.storage_series.push(t, self.storage_total);
        self.miss_series.push(t, self.miss_total);
        self.total_series.push(t, self.total());
        self.instances_series.push(t, instances as f64);
        out
    }

    /// Close an epoch for a vertically billed (ideal TTL) run: storage was
    /// already accrued via [`Self::record_storage_dollars`].
    pub fn end_epoch_vertical(&mut self, t: TimeUs) -> EpochCosts {
        self.miss_total += self.epoch_miss;
        let out = EpochCosts {
            t,
            storage: 0.0,
            miss: self.epoch_miss,
            miss_count: self.epoch_miss_count,
            instances: 0,
        };
        self.epoch_miss = 0.0;
        self.epoch_miss_count = 0;
        self.epochs += 1;
        self.storage_series.push(t, self.storage_total);
        self.miss_series.push(t, self.miss_total);
        self.total_series.push(t, self.total());
        out
    }

    pub fn storage_total(&self) -> f64 {
        self.storage_total
    }

    pub fn miss_total(&self) -> f64 {
        // Include the open epoch so totals are usable mid-run.
        self.miss_total + self.epoch_miss
    }

    pub fn total(&self) -> f64 {
        self.storage_total + self.miss_total()
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }
}

/// Costs attributed to one closed epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochCosts {
    pub t: TimeUs,
    pub storage: f64,
    pub miss: f64,
    pub miss_count: u64,
    pub instances: u32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostConfig;
    use crate::HOUR;

    #[test]
    fn storage_bills_per_instance_hour() {
        let mut t = CostTracker::new(CostConfig::default());
        let e = t.end_epoch(HOUR, 8);
        assert!((e.storage - 8.0 * 0.017).abs() < 1e-12);
        assert_eq!(e.instances, 8);
        assert!((t.total() - 0.136).abs() < 1e-9);
    }

    #[test]
    fn miss_costs_accumulate_per_epoch() {
        let mut t = CostTracker::new(CostConfig::default());
        for _ in 0..1000 {
            t.record_miss(4096);
        }
        let e = t.end_epoch(HOUR, 1);
        assert_eq!(e.miss_count, 1000);
        assert!((e.miss - 1000.0 * 1.4676e-7).abs() < 1e-12);
        // epoch counters reset
        let e2 = t.end_epoch(2 * HOUR, 1);
        assert_eq!(e2.miss_count, 0);
        assert_eq!(e2.miss, 0.0);
    }

    #[test]
    fn series_are_cumulative_and_aligned() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        t.end_epoch(HOUR, 2);
        t.record_miss(1);
        t.record_miss(1);
        t.end_epoch(2 * HOUR, 3);
        assert_eq!(t.storage_series.len(), 2);
        let (_, s2) = t.storage_series.last().unwrap();
        assert!((s2 - 5.0 * 0.017).abs() < 1e-12);
        let (_, m2) = t.miss_series.last().unwrap();
        assert!((m2 - 3.0 * 1.4676e-7).abs() < 1e-15);
        let (_, tot) = t.total_series.last().unwrap();
        assert!((tot - (s2 + m2)).abs() < 1e-12);
        assert_eq!(t.epochs(), 2);
    }

    #[test]
    fn vertical_billing_accrues_directly() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_storage_dollars(0.5);
        t.record_miss(1);
        let e = t.end_epoch_vertical(HOUR);
        assert_eq!(e.storage, 0.0); // storage accrued out of band
        assert!((t.storage_total() - 0.5).abs() < 1e-12);
        assert!(t.total() > 0.5);
    }

    #[test]
    fn open_epoch_included_in_running_totals() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        assert!(t.miss_total() > 0.0);
        assert_eq!(t.total(), t.miss_total());
    }
}
