//! Cost accounting (§2.3): storage cost `C^s(1,k) = Σ_h c^s·I(h)` billed
//! per epoch, miss cost `C^m = Σ_n m_{r(n)}` accrued per miss, and the
//! per-run cumulative series of Figs. 6–8.
//!
//! Multi-tenant runs additionally keep one [`TenantLedger`] per tenant:
//! misses are billed at `weight_t × m_o` (the tenant's miss-cost
//! multiplier) and attributed to the requesting tenant, so fig10 can
//! report who spent what on the shared cluster.

use crate::config::CostConfig;
use crate::metrics::TimeSeries;
use crate::{TenantId, TimeUs};

/// Per-tenant slice of the miss bill.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TenantLedger {
    pub misses: u64,
    pub miss_dollars: f64,
}

/// Running cost ledger for one policy run.
#[derive(Debug)]
pub struct CostTracker {
    cfg: CostConfig,
    /// Total storage dollars so far.
    storage_total: f64,
    /// Total miss dollars so far.
    miss_total: f64,
    /// Miss dollars accrued within the current epoch.
    epoch_miss: f64,
    /// Misses within the current epoch.
    epoch_miss_count: u64,
    /// Per-tenant miss attribution, indexed by tenant id (grown on
    /// demand; single-tenant runs only ever touch slot 0).
    tenant_ledgers: Vec<TenantLedger>,
    /// Per-tenant miss-cost multipliers, indexed by tenant id (missing =
    /// 1.0).
    tenant_weights: Vec<f64>,
    /// Cumulative series sampled at epoch boundaries.
    pub storage_series: TimeSeries,
    pub miss_series: TimeSeries,
    pub total_series: TimeSeries,
    /// Instances billed per epoch.
    pub instances_series: TimeSeries,
    epochs: u64,
}

impl CostTracker {
    pub fn new(cfg: CostConfig) -> Self {
        CostTracker {
            cfg,
            storage_total: 0.0,
            miss_total: 0.0,
            epoch_miss: 0.0,
            epoch_miss_count: 0,
            tenant_ledgers: Vec::new(),
            tenant_weights: Vec::new(),
            storage_series: TimeSeries::new("storage_cum"),
            miss_series: TimeSeries::new("miss_cum"),
            total_series: TimeSeries::new("total_cum"),
            instances_series: TimeSeries::new("instances"),
            epochs: 0,
        }
    }

    pub fn config(&self) -> &CostConfig {
        &self.cfg
    }

    /// Set tenant `t`'s miss-cost multiplier (default 1.0).
    pub fn set_tenant_weight(&mut self, t: TenantId, weight: f64) {
        let i = t as usize;
        if self.tenant_weights.len() <= i {
            self.tenant_weights.resize(i + 1, 1.0);
        }
        self.tenant_weights[i] = weight;
    }

    /// Miss-cost multiplier for tenant `t`.
    #[inline]
    pub fn tenant_weight(&self, t: TenantId) -> f64 {
        self.tenant_weights.get(t as usize).copied().unwrap_or(1.0)
    }

    /// Tenant `t`'s cumulative miss attribution (zero if never seen).
    pub fn tenant_ledger(&self, t: TenantId) -> TenantLedger {
        self.tenant_ledgers
            .get(t as usize)
            .copied()
            .unwrap_or_default()
    }

    /// All per-tenant ledgers, indexed by tenant id.
    pub fn tenant_ledgers(&self) -> &[TenantLedger] {
        &self.tenant_ledgers
    }

    /// Record one miss for an object of `size` bytes (tenant 0).
    #[inline]
    pub fn record_miss(&mut self, size: u64) {
        self.record_miss_for(0, size);
    }

    /// Record one miss by tenant `t` for an object of `size` bytes,
    /// billed at the tenant's weighted miss cost.
    #[inline]
    pub fn record_miss_for(&mut self, t: TenantId, size: u64) {
        let m = self.cfg.miss_cost(size) * self.tenant_weight(t);
        self.epoch_miss += m;
        self.epoch_miss_count += 1;
        let i = t as usize;
        if self.tenant_ledgers.len() <= i {
            self.tenant_ledgers.resize(i + 1, TenantLedger::default());
        }
        self.tenant_ledgers[i].misses += 1;
        self.tenant_ledgers[i].miss_dollars += m;
    }

    /// Record an arbitrary storage charge (used by the ideal TTL cache,
    /// billed on instantaneous occupancy rather than per instance).
    #[inline]
    pub fn record_storage_dollars(&mut self, dollars: f64) {
        self.storage_total += dollars;
    }

    /// Close the epoch that just ended at `t`, billing `instances` nodes
    /// for the whole epoch (§2.3: turning a node off early is paid anyway).
    pub fn end_epoch(&mut self, t: TimeUs, instances: u32) -> EpochCosts {
        let storage = instances as f64 * self.cfg.instance.dollars_per_hour
            * (self.cfg.epoch_us as f64 / crate::HOUR as f64);
        self.storage_total += storage;
        self.miss_total += self.epoch_miss;
        let out = EpochCosts {
            t,
            storage,
            miss: self.epoch_miss,
            miss_count: self.epoch_miss_count,
            instances,
        };
        self.epoch_miss = 0.0;
        self.epoch_miss_count = 0;
        self.epochs += 1;
        self.storage_series.push(t, self.storage_total);
        self.miss_series.push(t, self.miss_total);
        self.total_series.push(t, self.total());
        self.instances_series.push(t, instances as f64);
        out
    }

    /// Close an epoch for a vertically billed (ideal TTL) run: storage was
    /// already accrued via [`Self::record_storage_dollars`].
    pub fn end_epoch_vertical(&mut self, t: TimeUs) -> EpochCosts {
        self.miss_total += self.epoch_miss;
        let out = EpochCosts {
            t,
            storage: 0.0,
            miss: self.epoch_miss,
            miss_count: self.epoch_miss_count,
            instances: 0,
        };
        self.epoch_miss = 0.0;
        self.epoch_miss_count = 0;
        self.epochs += 1;
        self.storage_series.push(t, self.storage_total);
        self.miss_series.push(t, self.miss_total);
        self.total_series.push(t, self.total());
        out
    }

    pub fn storage_total(&self) -> f64 {
        self.storage_total
    }

    pub fn miss_total(&self) -> f64 {
        // Include the open epoch so totals are usable mid-run.
        self.miss_total + self.epoch_miss
    }

    pub fn total(&self) -> f64 {
        self.storage_total + self.miss_total()
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }
}

/// Costs attributed to one closed epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochCosts {
    pub t: TimeUs,
    pub storage: f64,
    pub miss: f64,
    pub miss_count: u64,
    pub instances: u32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostConfig;
    use crate::HOUR;

    #[test]
    fn storage_bills_per_instance_hour() {
        let mut t = CostTracker::new(CostConfig::default());
        let e = t.end_epoch(HOUR, 8);
        assert!((e.storage - 8.0 * 0.017).abs() < 1e-12);
        assert_eq!(e.instances, 8);
        assert!((t.total() - 0.136).abs() < 1e-9);
    }

    #[test]
    fn miss_costs_accumulate_per_epoch() {
        let mut t = CostTracker::new(CostConfig::default());
        for _ in 0..1000 {
            t.record_miss(4096);
        }
        let e = t.end_epoch(HOUR, 1);
        assert_eq!(e.miss_count, 1000);
        assert!((e.miss - 1000.0 * 1.4676e-7).abs() < 1e-12);
        // epoch counters reset
        let e2 = t.end_epoch(2 * HOUR, 1);
        assert_eq!(e2.miss_count, 0);
        assert_eq!(e2.miss, 0.0);
    }

    #[test]
    fn series_are_cumulative_and_aligned() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        t.end_epoch(HOUR, 2);
        t.record_miss(1);
        t.record_miss(1);
        t.end_epoch(2 * HOUR, 3);
        assert_eq!(t.storage_series.len(), 2);
        let (_, s2) = t.storage_series.last().unwrap();
        assert!((s2 - 5.0 * 0.017).abs() < 1e-12);
        let (_, m2) = t.miss_series.last().unwrap();
        assert!((m2 - 3.0 * 1.4676e-7).abs() < 1e-15);
        let (_, tot) = t.total_series.last().unwrap();
        assert!((tot - (s2 + m2)).abs() < 1e-12);
        assert_eq!(t.epochs(), 2);
    }

    #[test]
    fn vertical_billing_accrues_directly() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_storage_dollars(0.5);
        t.record_miss(1);
        let e = t.end_epoch_vertical(HOUR);
        assert_eq!(e.storage, 0.0); // storage accrued out of band
        assert!((t.storage_total() - 0.5).abs() < 1e-12);
        assert!(t.total() > 0.5);
    }

    #[test]
    fn open_epoch_included_in_running_totals() {
        let mut t = CostTracker::new(CostConfig::default());
        t.record_miss(1);
        assert!(t.miss_total() > 0.0);
        assert_eq!(t.total(), t.miss_total());
    }

    #[test]
    fn tenant_ledgers_attribute_weighted_misses() {
        let mut t = CostTracker::new(CostConfig::default());
        let m = t.config().miss_cost_dollars;
        t.set_tenant_weight(1, 3.0);
        t.set_tenant_weight(2, 0.5);
        t.record_miss_for(1, 4096);
        t.record_miss_for(1, 4096);
        t.record_miss_for(2, 4096);
        t.record_miss(4096); // tenant 0, weight 1.0
        let l0 = t.tenant_ledger(0);
        let l1 = t.tenant_ledger(1);
        let l2 = t.tenant_ledger(2);
        assert_eq!((l0.misses, l1.misses, l2.misses), (1, 2, 1));
        assert!((l1.miss_dollars - 2.0 * 3.0 * m).abs() < 1e-15);
        assert!((l2.miss_dollars - 0.5 * m).abs() < 1e-15);
        assert!((l0.miss_dollars - m).abs() < 1e-15);
        // The aggregate bill is the sum of the ledgers.
        let sum = l0.miss_dollars + l1.miss_dollars + l2.miss_dollars;
        assert!((t.miss_total() - sum).abs() < 1e-15);
        // Unknown tenants read as zero / weight 1.
        assert_eq!(t.tenant_ledger(40), TenantLedger::default());
        assert_eq!(t.tenant_weight(40), 1.0);
    }
}
