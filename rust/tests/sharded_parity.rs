//! Sharded-engine parity: `[engine] shards = N` must reproduce the
//! single-shard run *bit-for-bit* — epoch-by-epoch bills, per-tenant
//! epoch rows, retirement reconciliations, and the final RunReport
//! totals — on a multi-tenant trace with mid-run ADMIT/RETIRE churn.
//!
//! The configs below pin the exactness class where bit parity is a hard
//! guarantee rather than an approximation: a clamped controller
//! (`t_min == t_max == t_init`, so every shard's local controller holds
//! the same constant TTL), `min_instances == max_instances` (no resizes,
//! hence no hash-slot shuffles and no spurious misses), ample per-shard
//! capacity (no evictions, so hit/miss is a pure function of TTL and
//! time, independent of placement), the default flat per-miss cost, and
//! grant enforcement off. Within that class every divergence is a real
//! bug in the barrier merge, not float noise, so the assertions compare
//! `f64::to_bits` — never an epsilon.
//!
//! With telemetry on, the epoch decision journal is held to the same
//! standard: every record the sharded barrier emits must match the
//! `shards = 1` journal field-for-field (and turning telemetry on must
//! not perturb a single decision).
//!
//! `ELASTICTL_TEST_SHARDS=N` narrows the shard matrix to one width (the
//! CI shards leg runs the suite at 4); the default matrix is {2, 4}.

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::{self, EngineBuilder, ShardedEngine};
use elastictl::telemetry::EpochDecisionRecord;
use elastictl::tenant::{TenantAllocation, TenantSpec};
use elastictl::trace::{Request, SynthConfig, SynthGenerator, TenantEvent};
use elastictl::{TimeUs, MINUTE};

/// One step of the replayed workload: a request or a lifecycle event.
enum Op {
    Req(Request),
    Event(TenantEvent),
}

const ADMIT_T3: TimeUs = 45 * MINUTE;
const RETIRE_T2: TimeUs = 75 * MINUTE;

/// Shard widths under test: {2, 4} by default, or the single width named
/// by `ELASTICTL_TEST_SHARDS` (the CI shards matrix leg sets 4).
fn test_shards() -> Vec<u32> {
    match std::env::var("ELASTICTL_TEST_SHARDS") {
        Ok(s) => vec![s.parse().expect("ELASTICTL_TEST_SHARDS must be a shard count")],
        Err(_) => vec![2, 4],
    }
}

/// Two simulated hours across tenants 0..=2, with tenant 3 admitted at
/// 45 min (1.5× miss cost, an 8 MB reservation) and tenant 2 retired at
/// 75 min — after which its traffic share moves to tenant 3.
fn churn_ops() -> Vec<Op> {
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 25.0;
    let trace = SynthGenerator::new(synth).generate();

    let mut ops = Vec::with_capacity(trace.len() + 2);
    let mut admitted = false;
    let mut retired = false;
    for (i, r) in trace.iter().enumerate() {
        if !admitted && r.ts >= ADMIT_T3 {
            ops.push(Op::Event(
                TenantEvent::admit(ADMIT_T3, 3)
                    .with_multiplier(1.5)
                    .with_reserved_bytes(8_000_000),
            ));
            admitted = true;
        }
        if !retired && r.ts >= RETIRE_T2 {
            ops.push(Op::Event(TenantEvent::retire(RETIRE_T2, 2)));
            retired = true;
        }
        let tenant = if retired {
            // Tenant 2 is draining; its slot routes to the newcomer.
            match i % 3 {
                0 => 0,
                1 => 1,
                _ => 3,
            }
        } else if admitted {
            (i % 4) as u16
        } else {
            (i % 3) as u16
        };
        ops.push(Op::Req(r.with_tenant(tenant)));
    }
    assert!(admitted && retired, "trace too short for the churn schedule");
    ops
}

/// A config inside the bit-parity exactness class (see module docs).
fn parity_cfg(policy: PolicyKind) -> Config {
    let mut cfg = Config::with_policy(policy);
    cfg.cost.instance.ram_bytes = 400_000_000;
    cfg.cost.epoch_us = 10 * MINUTE;
    cfg.scaler.fixed_instances = 4;
    cfg.scaler.min_instances = 4;
    cfg.scaler.max_instances = 4;
    cfg.controller.t_init_secs = 300.0;
    cfg.controller.t_min_secs = 300.0;
    cfg.controller.t_max_secs = 300.0;
    if policy == PolicyKind::TenantTtl {
        cfg.tenants = vec![
            TenantSpec::new(0, "a").with_multiplier(2.0).with_reserved_bytes(4_000_000),
            TenantSpec::new(1, "b"),
            TenantSpec::new(2, "c").with_multiplier(0.5),
        ];
    }
    cfg
}

fn run_monolith(cfg: &Config, ops: &[Op]) -> engine::RunReport {
    let mut e = EngineBuilder::new(cfg).no_default_probes().build();
    for op in ops {
        match op {
            Op::Req(r) => {
                e.offer(r);
            }
            Op::Event(ev) => e.apply_event(ev).expect("lifecycle event applies"),
        }
    }
    e.finish()
}

type GrantsLog = Vec<(TimeUs, Vec<TenantAllocation>)>;

fn run_sharded(cfg: &Config, shards: u32, ops: &[Op]) -> (engine::RunReport, GrantsLog) {
    let mut cfg = cfg.clone();
    cfg.engine.shards = shards;
    let mut e = ShardedEngine::new(&cfg).expect("policy shards");
    for op in ops {
        match op {
            Op::Req(r) => e.offer(r),
            Op::Event(ev) => e.apply_event(ev).expect("lifecycle event applies"),
        }
    }
    let grants = e.grants_log().to_vec();
    (e.finish(), grants)
}

/// Every pinned aggregate, epoch row, tenant bill, and reconciliation —
/// compared on `to_bits`, so "close" is a failure.
fn assert_bit_identical(got: &engine::RunReport, want: &engine::RunReport, what: &str) {
    assert_eq!(got.requests, want.requests, "{what}: requests");
    assert_eq!(got.misses, want.misses, "{what}: misses");
    assert_eq!(got.spurious_misses, want.spurious_misses, "{what}: spurious");

    assert_eq!(got.epochs.len(), want.epochs.len(), "{what}: epoch count");
    for (g, w) in got.epochs.iter().zip(&want.epochs) {
        assert_eq!(g.t, w.t, "{what}: epoch boundary");
        assert_eq!(g.instances, w.instances, "{what}: instances at t={}", g.t);
        assert_eq!(g.miss_count, w.miss_count, "{what}: miss count at t={}", g.t);
        assert_eq!(
            (g.storage.to_bits(), g.miss.to_bits()),
            (w.storage.to_bits(), w.miss.to_bits()),
            "{what}: epoch dollars at t={} (got {g:?}, want {w:?})",
            g.t,
        );
    }

    assert_eq!(got.tenant_bills.len(), want.tenant_bills.len(), "{what}: bill rows");
    for (g, w) in got.tenant_bills.iter().zip(&want.tenant_bills) {
        assert_eq!((g.t, g.tenant), (w.t, w.tenant), "{what}: bill row order");
        assert_eq!(
            (g.storage.to_bits(), g.miss.to_bits()),
            (w.storage.to_bits(), w.miss.to_bits()),
            "{what}: tenant {} bill at t={} (got {g:?}, want {w:?})",
            g.tenant,
            g.t,
        );
    }

    assert_eq!(
        got.reconciliations.len(),
        want.reconciliations.len(),
        "{what}: reconciliation count"
    );
    for (g, w) in got.reconciliations.iter().zip(&want.reconciliations) {
        assert_eq!((g.tenant, g.at, g.misses), (w.tenant, w.at, w.misses), "{what}: recon id");
        assert_eq!(
            (g.miss_dollars.to_bits(), g.storage_dollars.to_bits(), g.total_dollars.to_bits()),
            (w.miss_dollars.to_bits(), w.storage_dollars.to_bits(), w.total_dollars.to_bits()),
            "{what}: tenant {} closed bill (got {g:?}, want {w:?})",
            g.tenant,
        );
    }

    assert_eq!(got.storage_cost.to_bits(), want.storage_cost.to_bits(), "{what}: storage total");
    assert_eq!(got.miss_cost.to_bits(), want.miss_cost.to_bits(), "{what}: miss total");
    assert_eq!(got.total_cost.to_bits(), want.total_cost.to_bits(), "{what}: grand total");
}

#[test]
fn sharded_matches_single_shard_bit_for_bit() {
    let ops = churn_ops();
    for policy in [PolicyKind::Fixed, PolicyKind::Ttl, PolicyKind::TenantTtl] {
        let cfg = parity_cfg(policy);
        let (want, want_grants) = run_sharded(&cfg, 1, &ops);
        assert!(want.requests > 100_000, "trace too small to be meaningful");
        assert!(want.epochs.len() >= 10, "trace spans too few epochs");
        for shards in test_shards() {
            let what = format!("{policy:?} shards={shards}");
            let (got, got_grants) = run_sharded(&cfg, shards, &ops);
            assert_bit_identical(&got, &want, &what);
            assert_eq!(got_grants, want_grants, "{what}: grants log");
        }
    }
    // The churn actually exercised retirement billing.
    let (base, _) = run_sharded(&parity_cfg(PolicyKind::TenantTtl), 1, &ops);
    assert_eq!(base.reconciliations.len(), 1);
    assert_eq!(base.reconciliations[0].tenant, 2);
}

#[test]
fn sharded_matches_the_monolithic_engine_bit_for_bit() {
    let ops = churn_ops();
    for policy in [PolicyKind::Fixed, PolicyKind::Ttl, PolicyKind::TenantTtl] {
        let cfg = parity_cfg(policy);
        let want = run_monolith(&cfg, &ops);
        for shards in test_shards() {
            let (got, _) = run_sharded(&cfg, shards, &ops);
            assert_bit_identical(&got, &want, &format!("{policy:?} shards={shards} vs monolith"));
        }
    }
}

#[test]
fn sharded_runs_are_deterministic_across_repeats() {
    let ops = churn_ops();
    let cfg = parity_cfg(PolicyKind::TenantTtl);
    let shards = *test_shards().last().unwrap();
    let (a, grants_a) = run_sharded(&cfg, shards, &ops);
    let (b, grants_b) = run_sharded(&cfg, shards, &ops);
    assert_bit_identical(&a, &b, "repeat run");
    assert_eq!(grants_a, grants_b, "repeat run: grants log");
}

/// [`parity_cfg`] with the decision journal and metric registry on.
fn telemetry_cfg(policy: PolicyKind) -> Config {
    let mut cfg = parity_cfg(policy);
    cfg.telemetry.enabled = true;
    cfg
}

/// The journal twin of [`assert_bit_identical`]: every retained epoch
/// decision record field, f64s compared on `to_bits`.
fn assert_journal_identical(
    got: &[EpochDecisionRecord],
    want: &[EpochDecisionRecord],
    what: &str,
) {
    let bits = |v: Option<f64>| v.map(f64::to_bits);
    assert_eq!(got.len(), want.len(), "{what}: journal length");
    for (g, w) in got.iter().zip(want) {
        assert_eq!((g.t, g.epoch, g.instances), (w.t, w.epoch, w.instances), "{what}: record id");
        assert_eq!(g.capacity_bytes, w.capacity_bytes, "{what}: capacity at t={}", g.t);
        assert_eq!(
            (g.storage_dollars.to_bits(), g.miss_dollars.to_bits()),
            (w.storage_dollars.to_bits(), w.miss_dollars.to_bits()),
            "{what}: epoch dollars at t={}",
            g.t,
        );
        assert_eq!(g.tenants.len(), w.tenants.len(), "{what}: tenant rows at t={}", g.t);
        for (gt, wt) in g.tenants.iter().zip(&w.tenants) {
            let ctx = format!("{what}: tenant {} at t={}", gt.tenant, g.t);
            assert_eq!(gt.tenant, wt.tenant, "{ctx}: row order");
            assert_eq!(
                (gt.demand_bytes, gt.granted_bytes, gt.reserved_bytes, gt.pooled_bytes),
                (wt.demand_bytes, wt.granted_bytes, wt.reserved_bytes, wt.pooled_bytes),
                "{ctx}: grant quantities",
            );
            assert_eq!(gt.cap_bytes, wt.cap_bytes, "{ctx}: cap");
            assert_eq!(bits(gt.ttl_clamp_secs), bits(wt.ttl_clamp_secs), "{ctx}: ttl clamp");
            assert_eq!(
                (gt.resident_before_bytes, gt.resident_bytes, gt.shed_bytes),
                (wt.resident_before_bytes, wt.resident_bytes, wt.shed_bytes),
                "{ctx}: residency",
            );
            assert_eq!(gt.denied_admissions, wt.denied_admissions, "{ctx}: denials");
            assert_eq!(gt.filter_denials, wt.filter_denials, "{ctx}: filter denials");
            assert_eq!(bits(gt.slo_miss_ratio), bits(wt.slo_miss_ratio), "{ctx}: slo target");
            assert_eq!(
                bits(gt.measured_miss_ratio),
                bits(wt.measured_miss_ratio),
                "{ctx}: measured miss ratio",
            );
            assert_eq!(gt.boost.to_bits(), wt.boost.to_bits(), "{ctx}: boost");
            assert_eq!(
                (gt.bill_storage_dollars.to_bits(), gt.bill_miss_dollars.to_bits()),
                (wt.bill_storage_dollars.to_bits(), wt.bill_miss_dollars.to_bits()),
                "{ctx}: attributed bill",
            );
            assert_eq!(
                bits(gt.reconciled_dollars),
                bits(wt.reconciled_dollars),
                "{ctx}: reconciled bill",
            );
            assert_eq!(gt.cause(), wt.cause(), "{ctx}: cause");
        }
    }
}

#[test]
fn sharded_journal_matches_single_shard_bit_for_bit() {
    let ops = churn_ops();
    for policy in [PolicyKind::Ttl, PolicyKind::TenantTtl] {
        let cfg = telemetry_cfg(policy);
        let (want, _) = run_sharded(&cfg, 1, &ops);
        assert!(want.journal.len() >= 10, "journal spans too few epochs");
        for shards in test_shards() {
            let what = format!("{policy:?} shards={shards} journal");
            let (got, _) = run_sharded(&cfg, shards, &ops);
            assert_journal_identical(&got.journal, &want.journal, &what);
        }
    }
    // The churn's retirement shows up in the journal, not just the bills:
    // exactly one record carries tenant 2's close-out reconciliation.
    let (base, _) = run_sharded(&telemetry_cfg(PolicyKind::TenantTtl), 1, &ops);
    let closed: Vec<_> = base
        .journal
        .iter()
        .filter_map(|r| r.tenant(2))
        .filter(|d| d.reconciled_dollars.is_some())
        .collect();
    assert_eq!(closed.len(), 1, "tenant 2 retirement must journal one reconciliation");
}

/// The Mth-request sketch is indexed by the shard router's own hash
/// (`mix64(scoped_object)` masked to a power-of-two cell count), so for
/// power-of-two shard counts every pair of sketch-colliding keys also
/// co-shards: the per-shard sketches evolve bit-identically to the
/// monolithic one, and so do the denial counters and the journal. The
/// co-sharding argument needs `shards | cells`, hence the power-of-two
/// filter on the shard matrix.
#[test]
fn sharded_mth_request_matches_single_shard_bit_for_bit() {
    use elastictl::config::AdmissionKind;
    let ops = churn_ops();
    for policy in [PolicyKind::Ttl, PolicyKind::TenantTtl] {
        let mut cfg = telemetry_cfg(policy);
        cfg.admission.filter = AdmissionKind::MthRequest;
        cfg.admission.m = 2;
        let (want, want_grants) = run_sharded(&cfg, 1, &ops);
        // The gate is live in this workload, not vacuously on: suppressed
        // first-sight inserts cost re-request misses vs the open run, and
        // the journal attributes denials to tenants.
        let (open, _) = run_sharded(&telemetry_cfg(policy), 1, &ops);
        assert!(
            want.misses > open.misses,
            "{policy:?}: M=2 never fired ({} vs {})",
            want.misses,
            open.misses
        );
        let journal_denials: u64 = want
            .journal
            .iter()
            .flat_map(|r| r.tenants.iter())
            .map(|d| d.filter_denials)
            .sum();
        assert!(journal_denials > 0, "{policy:?}: journal carries no filter denials");
        for shards in test_shards().into_iter().filter(|s| s.is_power_of_two()) {
            let what = format!("{policy:?} mth shards={shards}");
            let (got, got_grants) = run_sharded(&cfg, shards, &ops);
            assert_bit_identical(&got, &want, &what);
            assert_eq!(got_grants, want_grants, "{what}: grants log");
            assert_journal_identical(&got.journal, &want.journal, &what);
        }
    }
}

#[test]
fn telemetry_leaves_sharded_decisions_bit_identical() {
    let ops = churn_ops();
    let shards = *test_shards().last().unwrap();
    let (want, want_grants) = run_sharded(&parity_cfg(PolicyKind::TenantTtl), shards, &ops);
    assert!(want.journal.is_empty() && want.telemetry.is_empty(), "off means off");
    let (got, got_grants) = run_sharded(&telemetry_cfg(PolicyKind::TenantTtl), shards, &ops);
    assert!(!got.journal.is_empty() && !got.telemetry.is_empty(), "on means on");
    assert_bit_identical(&got, &want, "telemetry on vs off");
    assert_eq!(got_grants, want_grants, "telemetry on vs off: grants log");
}
