//! Randomized property tests over the policy stack (the crate's offline
//! proptest driver): cost-accounting identities, cluster invariants under
//! arbitrary resize sequences, virtual-cache size consistency, MRC
//! monotonicity, and TTL-OPT optimality against perturbed policies.

use elastictl::cache::{LruCache, Store};
use elastictl::cluster::Cluster;
use elastictl::config::{ClusterConfig, Config, CostConfig, PolicyKind};
use elastictl::mrc::{MrcProfiler, OlkenProfiler};
use elastictl::sim::run;
use elastictl::trace::{Request, VecSource};
use elastictl::ttlopt::{next_request_times, solve};
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;

fn random_trace(rng: &mut Pcg, max_len: usize, catalogue: u64) -> Vec<Request> {
    let len = 10 + rng.below_usize(max_len.max(11) - 10);
    let mut ts = 0u64;
    (0..len)
        .map(|_| {
            ts += rng.below(5_000_000) + 1;
            let obj = rng.below(catalogue);
            Request::new(ts, obj, (64 + rng.below(1_000_000)) as u32)
        })
        .collect()
}

#[test]
fn prop_cluster_slots_always_partition() {
    check("cluster_slots_partition", 0xC1, |rng| {
        let mut cluster = Cluster::new(&ClusterConfig::default(), 1_000_000, 1 + rng.below(8) as u32);
        for _ in 0..6 {
            let target = 1 + rng.below(20) as u32;
            cluster.resize(target);
            assert_eq!(cluster.len(), target.max(1) as usize);
            let total: usize = (0..cluster.len())
                .map(|i| cluster.slots_of_instance(i))
                .sum();
            assert_eq!(total, 16384, "slots lost after resize to {target}");
            // Routing always lands on a live instance.
            for obj in 0..64u64 {
                assert!(cluster.route(obj) < cluster.len());
            }
        }
    });
}

#[test]
fn prop_lru_used_equals_sum_of_resident_sizes() {
    check("lru_used_consistency", 0xC2, |rng| {
        let cap = 1_000 + rng.below(100_000);
        let mut lru = LruCache::new(cap);
        for _ in 0..300 {
            let obj = rng.below(200);
            let size = 1 + rng.below(cap / 4);
            if rng.chance(0.2) {
                lru.remove(obj);
            } else {
                lru.insert(obj, size);
            }
            let sum: u64 = lru.iter_mru().map(|(_, s)| s).sum();
            assert_eq!(sum, lru.used());
            assert!(lru.used() <= cap);
        }
    });
}

#[test]
fn prop_total_cost_is_storage_plus_miss() {
    check("cost_identity", 0xC3, |rng| {
        let trace = random_trace(rng, 4_000, 500);
        let mut cfg = Config::with_policy(if rng.chance(0.5) {
            PolicyKind::Ttl
        } else {
            PolicyKind::Fixed
        });
        cfg.cost.instance.ram_bytes = 10_000_000;
        cfg.cost.epoch_us = elastictl::MINUTE * (1 + rng.below(30));
        let res = run(&cfg, &mut VecSource::new(trace));
        assert!(
            (res.total_cost - (res.storage_cost + res.miss_cost)).abs() < 1e-9,
            "cost identity broken"
        );
        assert!(res.miss_ratio() > 0.0 && res.miss_ratio() <= 1.0);
        // Miss cost equals misses * per-miss cost (constant mode).
        let expect = res.misses as f64 * cfg.cost.miss_cost_dollars;
        assert!((res.miss_cost - expect).abs() < 1e-9);
    });
}

#[test]
fn prop_mrc_curve_is_monotone_and_bounded() {
    check("mrc_monotone", 0xC4, |rng| {
        let trace = random_trace(rng, 3_000, 300);
        let mut p = OlkenProfiler::sized(1 << 32);
        for r in &trace {
            p.record(r.obj, r.size_bytes());
        }
        let curve = p.curve();
        assert!(curve.is_monotone());
        for &(_, mr) in &curve.points {
            assert!((0.0..=1.0).contains(&mr), "mr={mr}");
        }
        // At infinite size only cold misses remain.
        let tail = curve.miss_ratio_at(u64::MAX / 2);
        let cold_ratio = p.cold_misses() / trace.len() as f64;
        assert!((tail - cold_ratio).abs() < 1e-9, "tail={tail} cold={cold_ratio}");
    });
}

#[test]
fn prop_ttlopt_never_worse_than_all_or_nothing() {
    // TTL-OPT is optimal; in particular it must not exceed the cost of
    // the trivial policies "never store" (all misses) and, per object,
    // "always store" — checked in aggregate here.
    check("ttlopt_lower_bound", 0xC5, |rng| {
        let trace = random_trace(rng, 2_000, 100);
        let cost = CostConfig::default();
        let res = solve(&trace, &cost);
        let never_store: f64 = trace.iter().map(|r| cost.miss_cost(r.size_bytes())).sum();
        assert!(
            res.total_cost <= never_store + 1e-12,
            "opt {} > never-store {}",
            res.total_cost,
            never_store
        );
        // Always-store: every gap billed as storage + first-miss per obj.
        let next = next_request_times(&trace);
        let mut always_store = 0.0;
        for (i, r) in trace.iter().enumerate() {
            match next[i] {
                Some(t_next) => {
                    always_store += cost.storage_rate(r.size_bytes())
                        * elastictl::us_to_secs(t_next - r.ts)
                }
                None => {}
            }
        }
        let cold: f64 = {
            let mut seen = std::collections::HashSet::new();
            trace
                .iter()
                .filter(|r| seen.insert(r.obj))
                .map(|r| cost.miss_cost(r.size_bytes()))
                .sum()
        };
        always_store += cold;
        assert!(
            res.total_cost <= always_store + 1e-12,
            "opt {} > always-store {}",
            res.total_cost,
            always_store
        );
    });
}

#[test]
fn prop_vcache_vsize_equals_sum_of_resident_ghosts() {
    use elastictl::config::ControllerConfig;
    use elastictl::vcache::VirtualCache;
    check("vcache_size_consistency", 0xC6, |rng| {
        let ctrl = ControllerConfig { t_init_secs: 30.0, ..Default::default() };
        let mut vc = VirtualCache::new(&ctrl, CostConfig::default());
        let mut now = 0u64;
        for _ in 0..500 {
            now += rng.below(10_000_000);
            let obj = rng.below(50);
            let size = 100 + rng.below(10_000);
            vc.on_request(now, obj, size);
        }
        // vsize is the exact sum over resident ghosts (lazy or not).
        assert!(vc.len() <= 50);
        assert!(vc.vsize() > 0 || vc.len() == 0);
        // After expiring far in the future, everything is gone.
        vc.expire(now + elastictl::DAY);
        assert_eq!(vc.vsize(), 0);
        assert_eq!(vc.len(), 0);
    });
}
