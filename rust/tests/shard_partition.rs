//! Randomized shard-partition properties (the crate's offline proptest
//! driver): the routing hash is a total deterministic partition of the
//! `(tenant, key)` space, every shard's resident-bytes ledger stays the
//! exact decomposition of its cluster occupancy under arbitrary traffic
//! and epoch churn, and tenant lifecycle events reach every shard
//! exactly once.
//!
//! Case count scales with `ELASTICTL_PROPTEST_CASES` (default 64); a
//! failure prints the `(seed, case)` pair for deterministic replay.

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::{shard_of, ShardedEngine};
use elastictl::trace::{Request, TenantEvent};
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::{TenantId, MINUTE};

fn random_trace(rng: &mut Pcg, len: usize, tenants: u16) -> Vec<Request> {
    let mut ts = 0u64;
    (0..len)
        .map(|_| {
            ts += rng.below(2_000_000) + 1;
            let obj = rng.below(500);
            let size = (64 + rng.below(100_000)) as u32;
            Request::new(ts, obj, size).with_tenant(rng.below(tenants as u64) as u16)
        })
        .collect()
}

fn sharded(policy: PolicyKind, shards: u32) -> ShardedEngine {
    let mut cfg = Config::with_policy(policy);
    cfg.cost.instance.ram_bytes = 100_000_000;
    cfg.cost.epoch_us = MINUTE;
    cfg.engine.shards = shards;
    ShardedEngine::new(&cfg).expect("policy shards")
}

#[test]
fn prop_shard_of_is_a_deterministic_total_partition() {
    check("shard_of_partition", 0x5A01, |rng| {
        let shards = 1 + rng.below(16) as u32;
        for _ in 0..200 {
            let tenant = rng.below(1 << 16) as TenantId;
            let obj = rng.next_u64();
            let s = shard_of(tenant, obj, shards);
            // In range, and the same shard on every evaluation: each
            // (tenant, key) pair has exactly one owner.
            assert!(s < shards as usize, "shard {s} out of range 0..{shards}");
            assert_eq!(s, shard_of(tenant, obj, shards), "routing must be deterministic");
            assert_eq!(shard_of(tenant, obj, 1), 0, "a single shard owns everything");
        }
    });
}

#[test]
fn prop_requests_land_on_their_owning_shard() {
    check("requests_follow_shard_of", 0x5A02, |rng| {
        let shards = 1 + rng.below(8) as u32;
        let trace = random_trace(rng, 200 + rng.below_usize(1_800), 4);
        let mut expected = vec![0u64; shards as usize];
        for r in &trace {
            expected[shard_of(r.tenant, r.obj, shards)] += 1;
        }
        let mut engine = sharded(PolicyKind::Ttl, shards);
        for r in &trace {
            engine.offer(r);
        }
        let stats = engine.shard_stats();
        assert_eq!(stats.len(), shards as usize);
        let got: Vec<u64> = stats.iter().map(|s| s.requests).collect();
        assert_eq!(got, expected, "per-shard request counts must match the routing hash");
        assert_eq!(got.iter().sum::<u64>(), trace.len() as u64, "no request lost or duplicated");
    });
}

#[test]
fn prop_resident_ledgers_decompose_used_bytes() {
    check("residents_partition_used", 0x5A03, |rng| {
        let shards = 1 + rng.below(8) as u32;
        let trace = random_trace(rng, 200 + rng.below_usize(1_800), 4);
        let mut engine = sharded(PolicyKind::Ttl, shards);
        for r in &trace {
            engine.offer(r);
        }
        let stats = engine.shard_stats();
        let mut total_used = 0u64;
        for (i, s) in stats.iter().enumerate() {
            let ledger_sum: u64 = s.tenant_residents.iter().map(|&(_, b)| b).sum();
            assert_eq!(
                ledger_sum,
                s.used_bytes,
                "shard {i}: tenant ledgers must sum to cluster used()"
            );
            total_used += s.used_bytes;
        }
        // Misses inserted something somewhere, and nothing was counted
        // on two shards at once: the per-shard ledgers decompose the
        // fleet-wide occupancy.
        let misses: u64 = stats.iter().map(|s| s.misses).sum();
        assert!(misses > 0, "a fresh cache must miss");
        assert!(total_used > 0, "misses must leave residents behind");
    });
}

#[test]
fn prop_lifecycle_events_reach_every_shard_exactly_once() {
    check("lifecycle_reaches_all_shards", 0x5A04, |rng| {
        let shards = 1 + rng.below(8) as u32;
        let mut engine = sharded(PolicyKind::TenantTtl, shards);
        let admits = 1 + rng.below(6) as u16;
        let retires = rng.below(admits as u64 + 1) as u16;
        let mut ts = 0u64;
        // Admit tenants 1..=admits, then retire the first `retires` of
        // them, with tenant-0 traffic interleaved so barriers fire.
        for id in 1..=admits {
            ts += rng.below(5_000_000) + 1;
            engine.apply_event(&TenantEvent::admit(ts, id)).expect("admit applies");
            engine.offer(&Request::new(ts, rng.below(100), 1_000));
        }
        for id in 1..=retires {
            ts += rng.below(5_000_000) + 1;
            engine.apply_event(&TenantEvent::retire(ts, id)).expect("retire applies");
            engine.offer(&Request::new(ts, rng.below(100), 1_000));
        }
        for (i, s) in engine.shard_stats().iter().enumerate() {
            assert_eq!(s.admit_events, admits as u64, "shard {i}: ADMIT fan-out");
            assert_eq!(s.retire_events, retires as u64, "shard {i}: RETIRE fan-out");
        }
    });
}
