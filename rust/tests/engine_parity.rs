//! Golden-parity regression for the engine port.
//!
//! The reference runners below are verbatim copies of the pre-engine
//! hand-rolled loops (`sim::run_policy` and `sim::run_ideal_ttl` as they
//! stood before `engine::Engine` existed), kept here as the golden spec:
//! for every policy the engine must reproduce their aggregates —
//! requests, misses, spurious misses, storage/miss/total dollars —
//! *bit-for-bit*, not approximately.
//!
//! A second suite pins the streaming file sources: replaying a trace
//! through `TraceReader`/`CsvReader` must produce byte-identical cost
//! totals to the in-memory `VecSource`.

use elastictl::balancer::Balancer;
use elastictl::config::{Config, PolicyKind};
use elastictl::cost::CostTracker;
use elastictl::engine;
use elastictl::runtime::AnalyticSizer;
use elastictl::scaler::{EpochSizer, FixedSizer, MrcSizer, TtlSizer};
use elastictl::tenant::TenantTtlSizer;
use elastictl::trace::{
    write_csv, write_trace, FileSource, Request, SynthConfig, SynthGenerator, VecSource,
};
use elastictl::vcache::VirtualCache;
use elastictl::{TimeUs, MINUTE};

/// Aggregates pinned by the parity check.
#[derive(Debug, PartialEq)]
struct Golden {
    requests: u64,
    misses: u64,
    spurious: u64,
    storage_bits: u64,
    miss_bits: u64,
    total_bits: u64,
}

impl Golden {
    fn of(requests: u64, misses: u64, spurious: u64, storage: f64, miss: f64, total: f64) -> Self {
        Golden {
            requests,
            misses,
            spurious,
            storage_bits: storage.to_bits(),
            miss_bits: miss.to_bits(),
            total_bits: total.to_bits(),
        }
    }
}

/// Verbatim copy of the seed's `sim::run_policy` epoch loop (series
/// sampling elided — it never touched the aggregates), including the
/// seed's inline initial-size dispatch and its inline sizer
/// construction — deliberately NOT `Config::initial_instances()` or
/// `make_sizer`/`engine::build_policy`, so a regression in any of those
/// shared helpers shows up here instead of cancelling out on both sides.
fn reference_run_policy(cfg: &Config, trace: &[Request]) -> Golden {
    let initial = match cfg.scaler.policy {
        PolicyKind::Fixed => cfg.scaler.fixed_instances,
        _ => cfg.scaler.min_instances.max(1),
    };
    let sizer: Box<dyn EpochSizer> = match cfg.scaler.policy {
        PolicyKind::Fixed => Box::new(FixedSizer::new(cfg.scaler.fixed_instances)),
        PolicyKind::Ttl => Box::new(TtlSizer::from_config(cfg)),
        PolicyKind::Mrc => Box::new(MrcSizer::from_config(cfg)),
        PolicyKind::TenantTtl => Box::new(TenantTtlSizer::from_config(cfg)),
        PolicyKind::Analytic => Box::new(AnalyticSizer::from_config(cfg)),
        PolicyKind::IdealTtl => unreachable!("ideal_ttl uses reference_run_ideal"),
    };
    let mut balancer = Balancer::from_config(cfg, sizer, initial);
    let mut costs = CostTracker::new(cfg.cost.clone());
    for spec in &cfg.tenants {
        costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
    }
    let epoch_us = cfg.cost.epoch_us.max(1);
    let mut epoch_end: TimeUs = epoch_us;
    let mut active_instances = balancer.cluster.len() as u32;
    let mut last_ts: TimeUs = 0;

    for req in trace {
        while req.ts >= epoch_end {
            costs.end_epoch(epoch_end, active_instances);
            balancer.cluster.reset_epoch_stats();
            active_instances = balancer.end_epoch(epoch_end);
            epoch_end += epoch_us;
        }
        balancer.handle(req, &mut costs);
        last_ts = req.ts;
    }
    // Bill the final (partial) epoch at full price (§2.3).
    costs.end_epoch(epoch_end.max(last_ts), active_instances);

    Golden::of(
        balancer.requests,
        balancer.misses,
        balancer.spurious_misses,
        costs.storage_total(),
        costs.miss_total(),
        costs.total(),
    )
}

/// Verbatim copy of the seed's `sim::run_ideal_ttl` loop.
fn reference_run_ideal(cfg: &Config, trace: &[Request]) -> Golden {
    let cost_cfg = cfg.cost.clone();
    let mut vc = VirtualCache::new(&cfg.controller, cost_cfg.clone());
    let mut costs = CostTracker::new(cost_cfg.clone());
    for spec in &cfg.tenants {
        costs.set_tenant_weight(spec.id, spec.miss_cost_multiplier);
    }
    let per_byte_sec = cost_cfg.storage_cost_per_byte_sec();
    let epoch_us = cost_cfg.epoch_us.max(1);

    let mut epoch_end: TimeUs = epoch_us;
    let mut last_ts: TimeUs = 0;
    let mut requests = 0u64;
    let mut misses = 0u64;

    for req in trace {
        // Storage accrues continuously on the current occupancy.
        let dt_secs = elastictl::us_to_secs(req.ts.saturating_sub(last_ts));
        costs.record_storage_dollars(vc.vsize() as f64 * per_byte_sec * dt_secs);
        last_ts = req.ts;
        while req.ts >= epoch_end {
            costs.end_epoch_vertical(epoch_end);
            epoch_end += epoch_us;
        }
        let obj = elastictl::tenant::scoped_object(req.tenant, req.obj);
        let out = vc.on_request(req.ts, obj, req.size_bytes());
        requests += 1;
        if !out.hit {
            misses += 1;
            costs.record_miss_for(req.tenant, req.size_bytes());
        }
    }
    costs.end_epoch_vertical(epoch_end.max(last_ts));

    Golden::of(
        requests,
        misses,
        0,
        costs.storage_total(),
        costs.miss_total(),
        costs.total(),
    )
}

fn golden_of_report(r: &engine::RunReport) -> Golden {
    Golden::of(
        r.requests,
        r.misses,
        r.spurious_misses,
        r.storage_cost,
        r.miss_cost,
        r.total_cost,
    )
}

/// Smoke-scale trace: deterministic tiny synth, truncated so the whole
/// matrix stays CI-fast but still spans several epochs and resizes.
fn parity_trace() -> Vec<Request> {
    let mut trace = SynthGenerator::new(SynthConfig::tiny()).generate();
    trace.truncate(200_000);
    trace
}

fn parity_cfg(policy: PolicyKind) -> Config {
    let mut cfg = Config::with_policy(policy);
    cfg.cost.instance.ram_bytes = 20_000_000;
    cfg.cost.epoch_us = 10 * MINUTE;
    cfg.scaler.fixed_instances = 4;
    cfg.scaler.max_instances = 32;
    cfg
}

#[test]
fn engine_matches_reference_loop_for_every_horizontal_policy() {
    let base = parity_trace();
    // Tag a copy across three tenants for the tenant policy.
    let tenanted: Vec<Request> = base
        .iter()
        .enumerate()
        .map(|(i, r)| r.with_tenant((i % 3) as u16))
        .collect();

    for policy in [
        PolicyKind::Fixed,
        PolicyKind::Ttl,
        PolicyKind::Mrc,
        PolicyKind::Analytic,
        PolicyKind::TenantTtl,
    ] {
        let mut cfg = parity_cfg(policy);
        if policy == PolicyKind::TenantTtl {
            use elastictl::tenant::TenantSpec;
            cfg.tenants = vec![
                TenantSpec::new(0, "a").with_multiplier(2.0),
                TenantSpec::new(1, "b"),
                TenantSpec::new(2, "c").with_multiplier(0.5),
            ];
        }
        let trace = if policy == PolicyKind::TenantTtl { &tenanted } else { &base };

        let want = reference_run_policy(&cfg, trace);
        let got = golden_of_report(&engine::run(&cfg, &mut VecSource::new(trace.clone())));
        assert_eq!(got, want, "policy {policy:?} diverged from the seed loop");
        assert!(got.requests > 100_000, "trace too small to be meaningful");
    }
}

#[test]
fn engine_matches_reference_loop_for_ideal_ttl() {
    let trace = parity_trace();
    let mut cfg = parity_cfg(PolicyKind::IdealTtl);
    cfg.controller.t_init_secs = 600.0;
    let want = reference_run_ideal(&cfg, &trace);
    let got = golden_of_report(&engine::run(&cfg, &mut VecSource::new(trace)));
    assert_eq!(got, want, "ideal_ttl diverged from the seed loop");
    assert_eq!(got.spurious, 0);
}

/// The admission layer's do-no-harm contract: the default config (no
/// `[admission]` section), an explicit `filter = none`, and even an
/// `mth_request` sketch at M=1 (which admits every first observation)
/// all leave the serving loop bit-identical to the seed. A real gate
/// (M=2) must then move the aggregates — proof the plumbing is live.
#[test]
fn admission_default_none_and_m1_keep_the_engine_bit_identical() {
    use elastictl::config::AdmissionKind;
    let mut trace = parity_trace();
    trace.truncate(100_000);
    let cfg = parity_cfg(PolicyKind::Ttl);
    let want = golden_of_report(&engine::run(&cfg, &mut VecSource::new(trace.clone())));

    let mut explicit_none = cfg.clone();
    explicit_none.admission.filter = AdmissionKind::None;
    let got = golden_of_report(&engine::run(&explicit_none, &mut VecSource::new(trace.clone())));
    assert_eq!(got, want, "explicit filter=none diverged from the default");

    let mut m1 = cfg.clone();
    m1.admission.filter = AdmissionKind::MthRequest;
    m1.admission.m = 1;
    let got = golden_of_report(&engine::run(&m1, &mut VecSource::new(trace.clone())));
    assert_eq!(got, want, "mth_request at M=1 admits everything, must not perturb");

    let mut m2 = cfg.clone();
    m2.admission.filter = AdmissionKind::MthRequest;
    m2.admission.m = 2;
    let got = golden_of_report(&engine::run(&m2, &mut VecSource::new(trace)));
    assert_eq!(got.requests, want.requests);
    assert!(
        got.misses > want.misses,
        "M=2 must suppress first-sight inserts and cost re-request misses \
         ({} vs {})",
        got.misses,
        want.misses
    );
}

#[test]
fn streaming_sources_match_vec_source_bit_for_bit() {
    let dir = elastictl::util::tempdir::tempdir().unwrap();
    let mut trace = parity_trace();
    trace.truncate(60_000);
    // Exercise the tenant column through both encodings.
    for (i, r) in trace.iter_mut().enumerate() {
        r.tenant = (i % 4) as u16;
    }
    let cfg = parity_cfg(PolicyKind::Ttl);

    let want = golden_of_report(&engine::run(&cfg, &mut VecSource::new(trace.clone())));

    let bin = dir.path().join("t.bin");
    write_trace(&bin, &trace).unwrap();
    let mut src = FileSource::open(&bin).unwrap();
    let got_bin = golden_of_report(&engine::run(&cfg, &mut src));
    src.check().unwrap();
    assert_eq!(got_bin, want, "binary streaming diverged from VecSource");

    let csv = dir.path().join("t.csv");
    write_csv(&csv, &trace).unwrap();
    let mut src = FileSource::open(&csv).unwrap();
    let got_csv = golden_of_report(&engine::run(&cfg, &mut src));
    src.check().unwrap();
    assert_eq!(got_csv, want, "CSV streaming diverged from VecSource");
}
