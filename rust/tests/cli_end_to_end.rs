//! End-to-end CLI tests: drive the compiled `elastictl` binary exactly as
//! a user would — generate a trace file, replay it under each policy,
//! compute the clairvoyant bound, and query the planner.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_elastictl")
}

fn run_ok(args: &[&str]) -> String {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn elastictl");
    assert!(
        out.status.success(),
        "elastictl {:?} failed:\nstdout: {}\nstderr: {}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn gen_run_ttlopt_plan_pipeline() {
    let dir = elastictl::util::tempdir::tempdir().unwrap();
    let trace = dir.path().join("t.bin");
    let trace_s = trace.to_str().unwrap();

    let out = run_ok(&["gen-trace", trace_s, "--kind", "irm", "--seed", "5"]);
    assert!(out.contains("wrote"), "{out}");

    // Every policy goes through the same engine entry point — `analytic`
    // included (the pre-engine dispatch panicked on it).
    for policy in ["fixed", "ttl", "mrc", "ideal_ttl", "analytic"] {
        let out = run_ok(&["run", trace_s, "--policy", policy]);
        assert!(out.contains(&format!("policy={policy}")), "{out}");
        assert!(out.contains("total=$"), "{out}");
    }

    let out = run_ok(&["ttlopt", trace_s]);
    assert!(out.contains("ttl-opt:"), "{out}");

    // plan works whether or not artifacts exist (oracle fallback).
    let out = run_ok(&["plan", trace_s]);
    assert!(out.contains("T*="), "{out}");
}

#[test]
fn csv_traces_are_accepted() {
    let dir = elastictl::util::tempdir::tempdir().unwrap();
    let csv = dir.path().join("t.csv");
    // Legacy tenant-less header must keep working.
    let mut text = String::from("ts_us,obj,size\n");
    for i in 0..2000u64 {
        text.push_str(&format!("{},{},{}\n", i * 50_000, i % 200, 1000 + i % 5000));
    }
    std::fs::write(&csv, text).unwrap();
    let out = run_ok(&["run", csv.to_str().unwrap(), "--policy", "ttl"]);
    assert!(out.contains("requests=2000"), "{out}");
}

#[test]
fn tenant_csv_runs_under_tenant_ttl_policy() {
    let dir = elastictl::util::tempdir::tempdir().unwrap();
    let csv = dir.path().join("mt.csv");
    let mut text = String::from("ts_us,obj,size,tenant\n");
    for i in 0..3000u64 {
        text.push_str(&format!(
            "{},{},{},{}\n",
            i * 50_000,
            i % 150,
            1000 + i % 5000,
            i % 3
        ));
    }
    std::fs::write(&csv, text).unwrap();
    let out = run_ok(&["run", csv.to_str().unwrap(), "--policy", "tenant_ttl"]);
    assert!(out.contains("policy=tenant_ttl"), "{out}");
    assert!(out.contains("requests=3000"), "{out}");
}

#[test]
fn config_file_is_honored() {
    let dir = elastictl::util::tempdir::tempdir().unwrap();
    let cfg = dir.path().join("cfg.toml");
    std::fs::write(&cfg, "[scaler]\nfixed_instances = 3\n").unwrap();
    let trace = dir.path().join("t.bin");
    run_ok(&["gen-trace", trace.to_str().unwrap(), "--kind", "irm"]);
    let out = run_ok(&[
        "--config",
        cfg.to_str().unwrap(),
        "run",
        trace.to_str().unwrap(),
        "--policy",
        "fixed",
        "--fixed-instances",
        "3",
    ]);
    assert!(out.contains("policy=fixed"), "{out}");
}

#[test]
fn unknown_args_fail_cleanly() {
    let out = Command::new(bin()).args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown command"), "{err}");
}
