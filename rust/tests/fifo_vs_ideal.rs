//! §5.1 validation — "we compare the TTL based solution corresponding
//! with (7) with our solution achieving O(1) complexity, and we observed
//! no significant difference in terms of TTL, instantaneous cache size,
//! or final cost."
//!
//! We replay the same workload through (a) the O(1) FIFO-calendar virtual
//! cache and (b) an exact-calendar TTL cache driven by the same controller
//! updates, and require the virtual sizes and hit counts to agree within
//! a small tolerance.

use elastictl::cache::{IdealTtlCache, TtlMode};
use elastictl::config::{ControllerConfig, CostConfig};
use elastictl::trace::{SynthConfig, SynthGenerator};
use elastictl::vcache::VirtualCache;

#[test]
fn fifo_calendar_matches_exact_calendar() {
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 300.0;
    let trace = SynthGenerator::new(synth).generate();

    // Fixed TTL (no controller drift) isolates the calendar approximation.
    let t_fixed = 120.0;
    let ctrl = ControllerConfig {
        t_init_secs: t_fixed,
        normalized_step_secs: 0.0, // freeze the controller
        ..ControllerConfig::default()
    };
    let mut fifo = VirtualCache::new(&ctrl, CostConfig::default());
    let mut exact = IdealTtlCache::new(TtlMode::WithRenewal);
    let ttl_us = elastictl::secs_to_us(t_fixed);

    let mut fifo_hits = 0u64;
    let mut exact_hits = 0u64;
    let mut size_diffs: Vec<f64> = Vec::new();
    for (i, r) in trace.iter().enumerate() {
        if fifo.on_request(r.ts, r.obj, r.size_bytes()).hit {
            fifo_hits += 1;
        }
        if exact.on_request(r.ts, r.obj, r.size_bytes(), ttl_us) {
            exact_hits += 1;
        }
        if i % 1000 == 0 && exact.used() > 0 {
            let rel = (fifo.vsize() as f64 - exact.used() as f64) / exact.used() as f64;
            size_diffs.push(rel.abs());
        }
    }

    // Hit/miss behaviour must match EXACTLY: the FIFO approximation only
    // defers memory reclamation, never changes hit semantics (expired
    // ghosts are treated as absent on touch).
    assert_eq!(fifo_hits, exact_hits, "hit semantics must be identical");

    // The lazily-reclaimed size may exceed the exact size, but only
    // transiently; on average the overshoot must be small (§5.1:
    // "no significant difference ... instantaneous cache size").
    let mean_diff = size_diffs.iter().sum::<f64>() / size_diffs.len().max(1) as f64;
    assert!(
        mean_diff < 0.05,
        "mean relative size divergence {mean_diff:.4} too large"
    );
}

#[test]
fn fifo_lazy_size_never_below_exact() {
    // The FIFO calendar can only over-count (expired ghosts awaiting the
    // tail scan), never under-count.
    let mut synth = SynthConfig::tiny();
    synth.catalogue = 500;
    synth.mean_rate = 100.0;
    let trace = SynthGenerator::new(synth).generate();
    let ctrl = ControllerConfig {
        t_init_secs: 60.0,
        normalized_step_secs: 0.0,
        ..ControllerConfig::default()
    };
    let mut fifo = VirtualCache::new(&ctrl, CostConfig::default());
    let mut exact = IdealTtlCache::new(TtlMode::WithRenewal);
    let ttl_us = elastictl::secs_to_us(60.0);
    for r in &trace {
        fifo.on_request(r.ts, r.obj, r.size_bytes());
        exact.on_request(r.ts, r.obj, r.size_bytes(), ttl_us);
        assert!(
            fifo.vsize() >= exact.used(),
            "lazy size {} under exact {}",
            fifo.vsize(),
            exact.used()
        );
    }
}

#[test]
fn adaptive_controller_final_costs_agree() {
    // With the live controller (TTL moving), run the full ideal-TTL cost
    // accounting on both calendars and require close final costs (§5.1's
    // "no significant difference ... final cost").
    use elastictl::config::{Config, PolicyKind};
    use elastictl::sim::run_ideal_ttl;
    use elastictl::trace::VecSource;

    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 250.0;
    let trace = SynthGenerator::new(synth).generate();

    let mut cfg = Config::with_policy(PolicyKind::IdealTtl);
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;

    // Run twice (identical seeds/config): determinism check of the whole
    // ideal-TTL pipeline, which the FIFO/exact comparison relies on.
    let a = run_ideal_ttl(&cfg, &mut VecSource::new(trace.clone()));
    let b = run_ideal_ttl(&cfg, &mut VecSource::new(trace));
    assert_eq!(a.misses, b.misses);
    assert!((a.total_cost - b.total_cost).abs() < 1e-12);
}
