//! Integration tests for the multi-tenant enforcement loop: the arbiter's
//! squeeze path (randomized invariants over grants) and the engine-level
//! guarantee that an epoch decision's caps and clamps actually bind on
//! the request path after `force_epoch`.

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::EngineBuilder;
use elastictl::tenant::{Arbiter, TenantDemand, TenantSpec};
use elastictl::trace::Request;
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::{MINUTE, SECOND};

fn random_demands(rng: &mut Pcg) -> Vec<TenantDemand> {
    let n = 1 + rng.below_usize(8);
    (0..n)
        .map(|i| {
            let demand = rng.below(50_000_000);
            let reserved = if rng.chance(0.4) { rng.below(20_000_000) } else { 0 };
            TenantDemand::new(i as u16, demand, 0.1 + rng.f64() * 10.0).with_reserved(reserved)
        })
        .collect()
}

#[test]
fn prop_sum_of_grants_never_exceeds_capacity() {
    check("grants_capacity", 0xA1, |rng| {
        let mut cfg = Config::default();
        cfg.scaler.min_instances = 1;
        cfg.scaler.max_instances = 1 + rng.below(12) as u32;
        let instance = 1_000_000 + rng.below(9_000_000);
        let arb = Arbiter::new(instance, &cfg.scaler);
        let demands = random_demands(rng);
        let (n, allocs) = arb.decide(&demands);
        assert!(n >= cfg.scaler.min_instances && n <= cfg.scaler.max_instances);
        let granted: u64 = allocs.iter().map(|a| a.granted_bytes).sum();
        assert!(
            granted <= arb.capacity_bytes(),
            "granted {granted} > capacity {}",
            arb.capacity_bytes()
        );
        for a in &allocs {
            // No grant exceeds what demand or the reservation justify.
            assert!(a.granted_bytes <= a.demand_bytes.max(a.reserved_bytes), "{a:?}");
        }
        // When nothing binds, every tenant gets at least its demand
        // (reservations may grant headroom beyond it).
        let total: u64 = demands.iter().map(|d| d.demand_bytes).sum();
        let reserved_total: u64 = demands.iter().map(|d| d.reserved_bytes).sum();
        if total + reserved_total <= arb.capacity_bytes() {
            for a in &allocs {
                assert!(a.granted_bytes >= a.demand_bytes, "{a:?}");
            }
        }
    });
}

#[test]
fn prop_grants_monotone_in_miss_cost() {
    check("grants_monotone", 0xA2, |rng| {
        // Equal demands, no reservations, scarce capacity: the grant
        // vector sorted by miss-cost weight must be non-increasing — a
        // cheaper tenant can never out-grant a more expensive one.
        let mut cfg = Config::default();
        cfg.scaler.min_instances = 1;
        cfg.scaler.max_instances = 1 + rng.below(4) as u32;
        let arb = Arbiter::new(1_000_000, &cfg.scaler);
        let demand = 1_000_000 + rng.below(10_000_000);
        let n = 2 + rng.below_usize(6);
        let demands: Vec<TenantDemand> = (0..n)
            .map(|i| TenantDemand::new(i as u16, demand, 0.1 + rng.f64() * 20.0))
            .collect();
        let (_, allocs) = arb.decide(&demands);
        let mut rows: Vec<(f64, u64)> =
            allocs.iter().map(|a| (a.weight, a.granted_bytes)).collect();
        rows.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for w in rows.windows(2) {
            assert!(w[0].1 >= w[1].1, "higher weight got less: {rows:?}");
        }
    });
}

#[test]
fn engine_applies_caps_and_clamps_after_force_epoch() {
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.controller.t_init_secs = 3600.0;
    cfg.cost.instance.ram_bytes = 1_000_000;
    cfg.cost.epoch_us = 10 * MINUTE;
    cfg.scaler.max_instances = 2;
    cfg.scaler.enforce_grants = true;
    cfg.tenants = vec![
        TenantSpec::new(0, "gold")
            .with_multiplier(10.0)
            .with_slo_miss_ratio(0.9),
        TenantSpec::new(1, "flood").with_multiplier(0.2),
    ];
    let mut engine = EngineBuilder::new(&cfg)
        .manual_epochs()
        .no_default_probes()
        .build();
    // Gold wants 0.8 MB; the flood tenant wants 4 MB of a 2 MB cluster.
    for i in 0..8u64 {
        engine.offer(&Request::new(i * SECOND, i, 100_000));
    }
    for i in 0..40u64 {
        engine.offer(&Request::new(10 * SECOND + i, 10_000 + i, 100_000).with_tenant(1));
    }
    let rows = engine.tenant_enforcement().expect("tenant policy exposes enforcement");
    assert!(rows.iter().all(|r| !r.decided), "no decision before the epoch");
    assert!(rows.iter().all(|r| r.enforced));

    let n = engine.force_epoch(60 * SECOND);
    assert!(n >= 1 && n <= 2, "n={n}");
    let rows = engine.tenant_enforcement().unwrap();
    let granted: u64 = rows.iter().map(|r| r.granted_bytes).sum();
    assert!(granted <= 2_000_000, "grants exceed the 2-instance capacity");
    let gold = rows.iter().find(|r| r.tenant == 0).unwrap();
    let flood = rows.iter().find(|r| r.tenant == 1).unwrap();
    assert_eq!(gold.granted_bytes, 800_000, "gold granted in full: {gold:?}");
    assert!(flood.granted_bytes < flood.demand_bytes, "{flood:?}");
    assert_eq!(flood.cap_bytes, Some(flood.granted_bytes));

    // The TTL clamp binds the live timer immediately.
    let clamp = flood.ttl_clamp_secs.expect("squeezed tenant is clamped");
    let ttls = engine.tenant_ttls().unwrap();
    let t_flood = ttls.iter().find(|(t, _)| *t == 1).unwrap().1;
    assert!(t_flood <= clamp + 1e-9, "timer {t_flood} above clamp {clamp}");

    // Fresh flood misses beyond the budget are refused on the request
    // path; the consumed budget never overruns the cap.
    for i in 0..40u64 {
        engine.offer(&Request::new(70 * SECOND + i, 20_000 + i, 100_000).with_tenant(1));
    }
    let rows = engine.tenant_enforcement().unwrap();
    let flood = rows.iter().find(|r| r.tenant == 1).unwrap();
    assert!(flood.denied_admissions > 0, "{flood:?}");
    assert!(flood.admitted_epoch_bytes <= flood.cap_bytes.unwrap(), "{flood:?}");

    // Gold, inside its grant, keeps admitting.
    let gold_denied = rows.iter().find(|r| r.tenant == 0).unwrap().denied_admissions;
    engine.offer(&Request::new(120 * SECOND, 900, 100_000));
    let rows = engine.tenant_enforcement().unwrap();
    assert_eq!(
        rows.iter().find(|r| r.tenant == 0).unwrap().denied_admissions,
        gold_denied,
        "gold must not be denied within its grant"
    );

    // The next epoch keeps the invariants: grants re-derived, budget
    // counters reset.
    engine.force_epoch(20 * MINUTE);
    let rows = engine.tenant_enforcement().unwrap();
    let granted: u64 = rows.iter().map(|r| r.granted_bytes).sum();
    assert!(granted <= 2_000_000);
    assert!(rows.iter().all(|r| r.admitted_epoch_bytes == 0), "budgets reset");
}
