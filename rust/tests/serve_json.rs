//! Wire-format conformance for the serve line protocol: every JSON
//! one-liner (`STATS` / `SLO` / `PLACEMENT` / `WHY`) must parse as valid
//! JSON and carry exactly the fields docs/PROTOCOL.md documents, and
//! `METRICS` must be well-formed Prometheus text terminated by `# EOF`.
//! The sharded front answers the same surface: its replies are pinned
//! here too, including the `shard="i"` labels in the merged exposition.
//!
//! The JSON validator is hand-rolled (the offline build carries no
//! serde): a strict recursive-descent parser that rejects trailing
//! garbage, unbalanced braces, and malformed numbers, and returns the
//! top-level object's keys in wire order so the tests can diff them
//! against the protocol document verbatim.

use elastictl::config::{Config, PolicyKind};
use elastictl::serve::ServerState;
use elastictl::srv::{spawn_sharded_state, Msg, SrvTx};
use elastictl::tenant::TenantSpec;
use std::sync::mpsc;

/// Strict JSON parser over the reply bytes (all replies are ASCII).
struct Json<'a> {
    b: &'a [u8],
    i: usize,
}

impl Json<'_> {
    /// Validate `s` as one JSON value; returns the top-level object's
    /// keys in order (empty for non-object values).
    fn parse(s: &str) -> Result<Vec<String>, String> {
        let mut p = Json { b: s.as_bytes(), i: 0 };
        p.ws();
        let keys = if p.peek() == Some(b'{') { p.object()? } else { p.value().map(|_| Vec::new())? };
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(keys)
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object().map(|_| ()),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(|_| ()),
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(char::from), self.i)),
        }
    }

    fn lit(&mut self, s: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<(), String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match text.parse::<f64>() {
            Ok(v) if v.is_finite() => Ok(()),
            _ => Err(format!("bad number {text:?} at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(c @ (b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't')) => {
                            out.push(char::from(c));
                            self.i += 1;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(c) => {
                    out.push(char::from(c));
                    self.i += 1;
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.eat(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Vec<String>, String> {
        self.eat(b'{')?;
        let mut keys = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(keys);
        }
        loop {
            self.ws();
            keys.push(self.string()?);
            self.ws();
            self.eat(b':')?;
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(keys);
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

/// Parse a reply, panicking with the reply text on invalid JSON.
fn keys_of(reply: &str) -> Vec<String> {
    Json::parse(reply).unwrap_or_else(|e| panic!("invalid JSON ({e}): {reply}"))
}

/// A tenant-aware, grant-enforcing, telemetry-on server with a tiny
/// cluster, oversubscribed by flood traffic and decided once — the state
/// every documented JSON command has something to say about.
fn decided_state() -> ServerState {
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.telemetry.enabled = true;
    cfg.controller.t_init_secs = 3600.0;
    cfg.cost.instance.ram_bytes = 1_000_000;
    cfg.scaler.max_instances = 2;
    cfg.scaler.enforce_grants = true;
    cfg.tenants = vec![
        TenantSpec::new(1, "gold").with_multiplier(10.0).with_slo_miss_ratio(0.2),
        TenantSpec::new(2, "flood").with_multiplier(0.1),
    ];
    let mut st = ServerState::new(&cfg);
    for i in 0..30 {
        st.handle_line(&format!("GET 2/obj{i} 100000"));
    }
    st.handle_line("GET 1/k 100000");
    st.handle_line("EPOCH");
    st
}

#[test]
fn global_stats_fields_match_protocol_doc() {
    let mut st = ServerState::new(&Config::with_policy(PolicyKind::Ttl));
    let documented = [
        "requests",
        "misses",
        "spurious",
        "filter_denials",
        "miss_ratio",
        "instances",
        "miss_cost",
        "ttl_secs",
        "tenants",
    ];
    // Pre-traffic: `miss_ratio` (and `ttl_secs`) are JSON `null`, and the
    // reply must already be valid JSON with the full documented key set.
    let reply = st.handle_line("STATS").unwrap();
    assert!(reply.contains("\"miss_ratio\":null"), "{reply}");
    assert_eq!(keys_of(&reply), documented, "{reply}");
    st.handle_line("GET k1 100");
    st.handle_line("GET k1 100");
    let reply = st.handle_line("STATS").unwrap();
    assert_eq!(keys_of(&reply), documented, "{reply}");
    assert!(reply.contains("\"miss_ratio\":0.500000"), "{reply}");
}

#[test]
fn tenant_stats_fields_match_protocol_doc() {
    let mut st = decided_state();
    let reply = st.handle_line("STATS 2").unwrap();
    assert_eq!(
        keys_of(&reply),
        ["tenant", "requests", "misses", "miss_cost", "physical_bytes", "ttl_secs", "state"],
        "{reply}"
    );
    // Tenant-oblivious policies document the same row minus `state`.
    let mut plain = ServerState::new(&Config::with_policy(PolicyKind::Ttl));
    plain.handle_line("GET k 100");
    let reply = plain.handle_line("STATS 0").unwrap();
    assert_eq!(
        keys_of(&reply),
        ["tenant", "requests", "misses", "miss_cost", "physical_bytes", "ttl_secs"],
        "{reply}"
    );
}

#[test]
fn bill_fields_match_protocol_doc() {
    // Only a retired tenant has a close-out reconciliation: admit a
    // guest, give it traffic, retire it, and close the epoch that
    // finishes the drain.
    let mut st = decided_state();
    st.handle_line("ADMIT 5 multiplier=2.0");
    st.handle_line("GET 5/k1 1000");
    st.handle_line("GET 5/k2 1000");
    st.handle_line("RETIRE 5");
    st.handle_line("EPOCH");
    let reply = st.handle_line("BILL 5").unwrap();
    assert_eq!(
        keys_of(&reply),
        ["tenant", "at", "misses", "miss_dollars", "storage_dollars", "total_dollars"],
        "{reply}"
    );
    // A tenant without a closed bill answers ERR, not fabricated JSON.
    let live = st.handle_line("BILL 1").unwrap();
    assert!(live.starts_with("ERR"), "{live}");
}

#[test]
fn slo_fields_match_protocol_doc() {
    let mut st = decided_state();
    for t in ["SLO 1", "SLO 2"] {
        let reply = st.handle_line(t).unwrap();
        assert_eq!(
            keys_of(&reply),
            [
                "tenant",
                "enforced",
                "decided",
                "demand_bytes",
                "granted_bytes",
                "cap_bytes",
                "admitted_epoch_bytes",
                "denied",
                "ttl_clamp_secs",
                "slo_miss_ratio",
                "measured_miss_ratio",
                "in_violation",
                "boost",
            ],
            "{reply}"
        );
    }
}

#[test]
fn placement_fields_match_protocol_doc() {
    let mut st = decided_state();
    let reply = st.handle_line("PLACEMENT").unwrap();
    assert_eq!(keys_of(&reply), ["policy", "instances", "tenants"], "{reply}");
    // And with per-tenant pins populated (hash_slot_pinned after EPOCH).
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.cluster.placement = elastictl::placement::PlacementKind::HashSlotPinned;
    cfg.tenants = vec![TenantSpec::new(1, "api")];
    let mut st = ServerState::new(&cfg);
    st.handle_line("GET 1/k1 1000");
    st.handle_line("EPOCH");
    let reply = st.handle_line("PLACEMENT").unwrap();
    assert_eq!(keys_of(&reply), ["policy", "instances", "tenants"], "{reply}");
    assert!(reply.contains("\"pins\":["), "{reply}");
}

#[test]
fn why_fields_match_protocol_doc() {
    let mut st = decided_state();
    let reply = st.handle_line("WHY 2").unwrap();
    assert_eq!(keys_of(&reply), ["t", "epoch", "instances", "cause", "decision"], "{reply}");
    // The nested decision record round-trips the journal schema exactly.
    let dec = &reply[reply.find("\"decision\":").unwrap() + "\"decision\":".len()..reply.len() - 1];
    assert_eq!(
        keys_of(dec),
        [
            "tenant",
            "demand_bytes",
            "granted_bytes",
            "reserved_bytes",
            "pooled_bytes",
            "cap_bytes",
            "ttl_clamp_secs",
            "resident_before_bytes",
            "resident_bytes",
            "shed_bytes",
            "denied_admissions",
            "filter_denials",
            "slo_miss_ratio",
            "measured_miss_ratio",
            "boost",
            "bill_storage_dollars",
            "bill_miss_dollars",
            "reconciled_dollars",
            "cause",
        ],
        "{dec}"
    );
}

#[test]
fn journal_jsonl_records_parse_too() {
    // The JSONL the engine writes (and WHY serves a row of) is the same
    // to_json(): every journaled record must be one valid JSON object.
    let st = decided_state();
    let journal = st.engine.journal().expect("telemetry on").borrow().to_jsonl();
    assert!(!journal.is_empty());
    for line in journal.lines() {
        let keys = keys_of(line);
        assert_eq!(
            keys,
            ["t", "epoch", "instances", "capacity_bytes", "storage_dollars", "miss_dollars",
             "tenants"],
            "{line}"
        );
    }
}

/// Walk a `METRICS` reply asserting Prometheus text grammar line by line
/// (comments are TYPE/HELP, samples are `name[{labels}] value`, the block
/// ends with `# EOF`); returns the sample count.
fn assert_prometheus_grammar(block: &str) -> usize {
    let mut samples = 0usize;
    let mut lines = block.lines().peekable();
    while let Some(line) = lines.next() {
        let last = lines.peek().is_none();
        if last {
            assert_eq!(line, "# EOF", "METRICS must terminate with # EOF: {line:?}");
            break;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("TYPE ") || rest.starts_with("HELP "),
                "bad comment line: {line:?}"
            );
            continue;
        }
        // A sample: `name value` or `name{label="v",...} value`.
        let (series, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line:?}"));
        assert!(value.parse::<f64>().is_ok() || value == "+Inf", "bad value: {line:?}");
        let name = series.split('{').next().unwrap();
        assert!(
            !name.is_empty()
                && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name: {line:?}"
        );
        let labels = &series[name.len()..];
        assert!(
            labels.is_empty() || (labels.starts_with('{') && labels.ends_with('}')),
            "bad label block: {line:?}"
        );
        samples += 1;
    }
    samples
}

#[test]
fn metrics_reply_is_prometheus_text() {
    let mut st = decided_state();
    let block = st.handle_line("METRICS").unwrap();
    let samples = assert_prometheus_grammar(&block);
    assert!(samples >= 10, "suspiciously few samples:\n{block}");
    // The documented request-path counters are present.
    for metric in ["elastictl_requests_total", "elastictl_misses_total", "elastictl_instances"] {
        assert!(block.contains(metric), "missing {metric}:\n{block}");
    }
}

// --- the sharded front answers the same surface ---

/// Drive one line through a sharded front thread and wait for the reply.
fn ask(tx: &SrvTx, line: &str) -> Option<String> {
    let (reply_tx, reply_rx) = mpsc::channel();
    tx.send(Msg::Line(line.to_string(), reply_tx)).unwrap();
    reply_rx.recv().unwrap()
}

/// The sharded twin of [`decided_state`]: same tenants, same flood, same
/// single decided epoch, behind `shards` workers.
fn sharded_decided(shards: u32) -> SrvTx {
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.engine.shards = shards;
    cfg.telemetry.enabled = true;
    cfg.controller.t_init_secs = 3600.0;
    cfg.cost.instance.ram_bytes = 1_000_000;
    cfg.scaler.max_instances = 2;
    cfg.scaler.enforce_grants = true;
    cfg.tenants = vec![
        TenantSpec::new(1, "gold").with_multiplier(10.0).with_slo_miss_ratio(0.2),
        TenantSpec::new(2, "flood").with_multiplier(0.1),
    ];
    let server = spawn_sharded_state(cfg, None).expect("tenant_ttl shards");
    for i in 0..30 {
        ask(&server.tx, &format!("GET 2/obj{i} 100000"));
    }
    ask(&server.tx, "GET 1/k 100000");
    ask(&server.tx, "EPOCH");
    server.tx
}

#[test]
fn sharded_global_stats_has_null_miss_ratio_before_traffic() {
    let mut cfg = Config::with_policy(PolicyKind::Ttl);
    cfg.engine.shards = 2;
    let server = spawn_sharded_state(cfg, None).unwrap();
    let reply = ask(&server.tx, "STATS").unwrap();
    assert!(reply.contains("\"miss_ratio\":null"), "{reply}");
    assert_eq!(
        keys_of(&reply),
        [
            "requests",
            "misses",
            "spurious",
            "filter_denials",
            "miss_ratio",
            "instances",
            "miss_cost",
            "ttl_secs",
            "tenants",
            "shards",
        ],
        "{reply}"
    );
}

#[test]
fn sharded_tenant_stats_fields_match_protocol_doc() {
    let tx = sharded_decided(2);
    let reply = ask(&tx, "STATS 2").unwrap();
    assert_eq!(
        keys_of(&reply),
        ["tenant", "requests", "misses", "miss_cost", "physical_bytes", "ttl_secs", "state"],
        "{reply}"
    );
    assert!(reply.contains("\"requests\":30"), "{reply}");
    assert!(reply.contains("\"state\":\"active\""), "{reply}");
}

#[test]
fn sharded_slo_fields_match_protocol_doc() {
    let tx = sharded_decided(2);
    for t in ["SLO 1", "SLO 2"] {
        let reply = ask(&tx, t).unwrap();
        assert_eq!(
            keys_of(&reply),
            [
                "tenant",
                "enforced",
                "decided",
                "demand_bytes",
                "granted_bytes",
                "cap_bytes",
                "admitted_epoch_bytes",
                "denied",
                "ttl_clamp_secs",
                "slo_miss_ratio",
                "measured_miss_ratio",
                "in_violation",
                "boost",
            ],
            "{reply}"
        );
    }
}

#[test]
fn sharded_placement_fields_match_protocol_doc() {
    let tx = sharded_decided(2);
    let reply = ask(&tx, "PLACEMENT").unwrap();
    assert_eq!(keys_of(&reply), ["policy", "instances", "tenants"], "{reply}");
}

#[test]
fn sharded_why_fields_match_protocol_doc() {
    let tx = sharded_decided(2);
    let reply = ask(&tx, "WHY 2").unwrap();
    assert_eq!(keys_of(&reply), ["t", "epoch", "instances", "cause", "decision"], "{reply}");
    let dec = &reply[reply.find("\"decision\":").unwrap() + "\"decision\":".len()..reply.len() - 1];
    assert_eq!(
        keys_of(dec),
        [
            "tenant",
            "demand_bytes",
            "granted_bytes",
            "reserved_bytes",
            "pooled_bytes",
            "cap_bytes",
            "ttl_clamp_secs",
            "resident_before_bytes",
            "resident_bytes",
            "shed_bytes",
            "denied_admissions",
            "filter_denials",
            "slo_miss_ratio",
            "measured_miss_ratio",
            "boost",
            "bill_storage_dollars",
            "bill_miss_dollars",
            "reconciled_dollars",
            "cause",
        ],
        "{dec}"
    );
}

#[test]
fn sharded_metrics_reply_is_prometheus_text_with_shard_labels() {
    let tx = sharded_decided(2);
    let block = ask(&tx, "METRICS").unwrap();
    let samples = assert_prometheus_grammar(&block);
    assert!(samples >= 10, "suspiciously few samples:\n{block}");
    // Per-shard series under shard labels, one per worker…
    for shard in 0..2 {
        assert!(
            block.contains(&format!("elastictl_requests_total{{shard=\"{shard}\"}}")),
            "missing shard {shard} series:\n{block}"
        );
    }
    // …the cluster-level sum under the plain name, and the shard-health
    // metrics the front records at every barrier.
    for metric in [
        "\nelastictl_requests_total ",
        "elastictl_shard_queue_depth{shard=\"0\"}",
        "elastictl_shard_batch_occupancy{shard=\"0\"}",
        "elastictl_shard_request_imbalance",
        "elastictl_epoch_barrier_wait_ns_count",
        "elastictl_epoch_merge_ns_count",
        "elastictl_instances",
    ] {
        assert!(block.contains(metric), "missing {metric:?}:\n{block}");
    }
}
