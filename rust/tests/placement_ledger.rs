//! Property suite for the physical placement subsystem: under randomized
//! insert / evict / shed / resize / grant sequences, across all three
//! placement policies and all three eviction kinds, the cluster's
//! per-tenant resident ledger must stay *exact*:
//!
//! * `Σ per-tenant ledger rows == Cluster::used()` (the tentpole
//!   invariant — eviction callbacks reported every byte), and
//! * every instance's per-tenant store tallies partition that instance's
//!   `used()` (the ledger's per-node counterpart).
//!
//! Underflow is caught two ways: the ledger's `debug_assert` fires inside
//! the test profile, and any silent saturation would break the Σ == used
//! equality on the next check.

use elastictl::cluster::Cluster;
use elastictl::config::{ClusterConfig, EvictionKind};
use elastictl::placement::{PlacementKind, TenantGrant};
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::TenantId;

const TENANTS: u16 = 5;
const INSTANCE_BYTES: u64 = 100_000;

fn ledger_invariants(c: &Cluster, ctx: &str) {
    assert_eq!(
        c.ledger_residents(),
        c.used(),
        "Σ ledger != used() after {ctx}"
    );
    let per_tenant: u64 = (0..TENANTS).map(|t| c.tenant_resident_bytes(t)).sum();
    assert_eq!(per_tenant, c.used(), "tenant rows don't partition used() after {ctx}");
    for inst in c.instances() {
        let tallies: u64 = (0..TENANTS).map(|t| inst.tenant_bytes_of(t)).sum();
        assert_eq!(
            tallies,
            inst.used(),
            "instance {} tallies don't partition its used() after {ctx}",
            inst.id
        );
    }
}

fn random_grants(rng: &mut Pcg) -> Vec<TenantGrant> {
    (0..TENANTS)
        .map(|tenant| {
            let granted_bytes = rng.below(4 * INSTANCE_BYTES);
            let reserved_bytes = if rng.chance(0.5) { rng.below(granted_bytes.max(1)) } else { 0 };
            TenantGrant { tenant, granted_bytes, reserved_bytes }
        })
        .collect()
}

fn exercise(placement: PlacementKind, eviction: EvictionKind, base_seed: u64) {
    let name = format!("ledger_{}_{}", placement.as_str(), eviction.as_str());
    check(&name, base_seed, |rng| {
        let mut cfg = ClusterConfig::default();
        cfg.placement = placement;
        cfg.eviction = eviction;
        cfg.seed = rng.next_u64();
        let mut c = Cluster::new(&cfg, INSTANCE_BYTES, 1 + rng.below(4) as u32);
        ledger_invariants(&c, "construction");
        for op in 0..300 {
            let roll = rng.f64();
            let ctx;
            if roll < 0.72 {
                // The hot path: tenant-tagged serve (inserts + evictions).
                let tenant = rng.below(TENANTS as u64) as TenantId;
                let obj = rng.below(400);
                let size = 1 + rng.below(INSTANCE_BYTES / 3);
                c.serve_for(tenant, obj, size);
                ctx = "serve_for";
            } else if roll < 0.80 {
                // Denied admission: lookup only, never touches the ledger.
                let before = c.ledger_residents();
                c.serve_no_insert_for(rng.below(TENANTS as u64) as TenantId, rng.below(400));
                assert_eq!(c.ledger_residents(), before, "no-insert touched the ledger");
                ctx = "serve_no_insert_for";
            } else if roll < 0.88 {
                // Occupancy-cap shedding.
                let tenant = rng.below(TENANTS as u64) as TenantId;
                let cap = rng.below(2 * INSTANCE_BYTES);
                let before = c.tenant_resident_bytes(tenant);
                let freed = c.shed_tenant(tenant, cap);
                assert_eq!(c.tenant_resident_bytes(tenant), before - freed);
                assert!(c.tenant_resident_bytes(tenant) <= cap, "shed must reach the cap");
                ctx = "shed_tenant";
            } else if roll < 0.94 {
                // Epoch-style grant application (re-pin / re-floor).
                let grants = random_grants(rng);
                c.apply_grants(&grants);
                ctx = "apply_grants";
            } else {
                // Elastic resize, growing and shrinking.
                c.resize(1 + rng.below(5) as u32);
                ctx = "resize";
            }
            if op % 10 == 9 || ctx != "serve_for" {
                ledger_invariants(&c, ctx);
            }
        }
        ledger_invariants(&c, "final");
    });
}

#[test]
fn prop_ledger_partitions_used_shared() {
    exercise(PlacementKind::Shared, EvictionKind::Lru, 0x1ED6E1);
}

#[test]
fn prop_ledger_partitions_used_shared_sampled() {
    exercise(PlacementKind::Shared, EvictionKind::SampledLru, 0x1ED6E2);
}

#[test]
fn prop_ledger_partitions_used_shared_slab() {
    exercise(PlacementKind::Shared, EvictionKind::Slab, 0x1ED6E3);
}

#[test]
fn prop_ledger_partitions_used_pinned() {
    exercise(PlacementKind::HashSlotPinned, EvictionKind::Lru, 0x1ED6E4);
}

#[test]
fn prop_ledger_partitions_used_pinned_sampled() {
    exercise(PlacementKind::HashSlotPinned, EvictionKind::SampledLru, 0x1ED6E5);
}

#[test]
fn prop_ledger_partitions_used_partition() {
    exercise(PlacementKind::SlabPartition, EvictionKind::Lru, 0x1ED6E6);
}

#[test]
fn prop_ledger_partitions_used_partition_sampled() {
    exercise(PlacementKind::SlabPartition, EvictionKind::SampledLru, 0x1ED6E7);
}

#[test]
fn prop_ledger_partitions_used_partition_slab() {
    exercise(PlacementKind::SlabPartition, EvictionKind::Slab, 0x1ED6E8);
}
