//! Billing-reconciliation property suite for online tenant churn: under
//! randomized request traffic with tenants admitted and retired mid-run,
//! across all three placement policies (and both enforcement settings),
//! the cost attribution must stay **exact** and retirement must actually
//! reclaim memory:
//!
//! * `Σ (per-epoch tenant bills) == total cluster bill`, bit for bit —
//!   the fold over [`elastictl::cost::CostTracker::tenant_bills`] in
//!   accumulation order reproduces `RunReport::total_cost` with `==`,
//!   not an epsilon, even when tenants join and leave mid-epoch;
//! * every retired tenant's reconciled bill equals the fold of its own
//!   per-epoch bill rows, exactly;
//! * after a RETIRE the tenant's ledger residents reach 0 within
//!   [`elastictl::tenant::MAX_DRAIN_EPOCHS`] epoch boundaries, and stay
//!   at 0 (a draining tenant's traffic is never cached again).

use elastictl::config::{Config, PolicyKind};
use elastictl::engine::{EngineBuilder, RunReport};
use elastictl::placement::PlacementKind;
use elastictl::tenant::{LifecycleState, TenantSpec, MAX_DRAIN_EPOCHS};
use elastictl::trace::Request;
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::{TenantId, MINUTE, SECOND};

const EPOCH_US: u64 = 10 * MINUTE;

fn churn_cfg(placement: PlacementKind, enforce: bool) -> Config {
    let mut cfg = Config::with_policy(PolicyKind::TenantTtl);
    cfg.controller.t_init_secs = 1800.0;
    cfg.cost.instance.ram_bytes = 1_000_000;
    cfg.cost.epoch_us = EPOCH_US;
    cfg.scaler.max_instances = 4;
    cfg.scaler.enforce_grants = enforce;
    cfg.cluster.placement = placement;
    cfg.tenants = vec![
        TenantSpec::new(0, "base").with_multiplier(2.0),
        TenantSpec::new(1, "bulk"),
    ];
    cfg
}

/// Fold the report's per-tenant epoch bills exactly as the tracker
/// accumulated them (per epoch in row order, then across epochs),
/// optionally restricted to one tenant.
fn fold_bills(report: &RunReport, tenant: Option<TenantId>) -> (f64, f64) {
    let (mut s, mut m) = (0.0, 0.0);
    let (mut se, mut me) = (0.0, 0.0);
    let mut cur = None;
    for b in &report.tenant_bills {
        if let Some(t) = tenant {
            if b.tenant != t {
                continue;
            }
        }
        if cur != Some(b.t) {
            s += se;
            m += me;
            se = 0.0;
            me = 0.0;
            cur = Some(b.t);
        }
        se += b.storage;
        me += b.miss;
    }
    (s + se, m + me)
}

/// One randomized churn run: random traffic over the roster tenants,
/// random mid-run admissions of new tenants, random retirements, then
/// the exactness and drain invariants on the report.
fn exercise(placement: PlacementKind, enforce: bool, base_seed: u64) {
    let name = format!(
        "churn_{}_{}",
        placement.as_str(),
        if enforce { "enforced" } else { "reporting" }
    );
    check(&name, base_seed, |rng: &mut Pcg| {
        let cfg = churn_cfg(placement, enforce);
        let mut engine = EngineBuilder::new(&cfg).build();
        // Live = admitted at some point and not yet retired.
        let mut live: Vec<TenantId> = vec![0, 1];
        let mut retired: Vec<TenantId> = Vec::new();
        let mut next_tenant: TenantId = 2;
        let mut ts: u64 = 0;

        let epochs = 4 + rng.below(4);
        for _ in 0..epochs {
            let epoch_start = ts;
            // A burst of requests spread over the epoch.
            let requests = 40 + rng.below(120);
            for _ in 0..requests {
                ts += rng.below(EPOCH_US / 200).max(1);
                // Mostly live tenants; occasionally a stray (lazily
                // admitted) or a retired tenant (served, never cached).
                let roll = rng.f64();
                let tenant = if roll < 0.85 || retired.is_empty() {
                    live[rng.below_usize(live.len())]
                } else {
                    retired[rng.below_usize(retired.len())]
                };
                let obj = rng.below(60);
                let size = (20_000 + rng.below(120_000)) as u32;
                engine.offer(&Request::new(ts, obj, size).with_tenant(tenant));
            }
            // Maybe admit a fresh tenant mid-epoch.
            if rng.chance(0.5) {
                let spec = TenantSpec::new(next_tenant, format!("t{next_tenant}"))
                    .with_multiplier(rng.range_f64(0.2, 5.0))
                    .with_reserved_bytes(rng.below(1_000_000));
                engine.admit_tenant(spec).unwrap();
                live.push(next_tenant);
                next_tenant += 1;
            }
            // Maybe retire a live tenant mid-epoch (keep at least one).
            if live.len() > 1 && rng.chance(0.4) {
                let idx = rng.below_usize(live.len());
                let tenant = live.swap_remove(idx);
                engine.retire_tenant(tenant).unwrap();
                retired.push(tenant);
            }
            // Close the epoch (drain + reconciliation happen here).
            ts = epoch_start + EPOCH_US + rng.below(SECOND);
            engine.advance_to(ts);
            // Every retired tenant must be fully drained within K
            // boundaries — and stay at zero residents afterwards.
            for &t in &retired {
                let life = engine.tenant_lifecycle_of(t).unwrap();
                if life.state() == LifecycleState::Retired {
                    assert_eq!(
                        engine.tenant_physical_bytes(t),
                        0,
                        "retired tenant {t} still holds bytes"
                    );
                }
                assert!(
                    life.drain_epochs <= MAX_DRAIN_EPOCHS,
                    "tenant {t} drained too slowly: {life:?}"
                );
            }
        }
        // Close out: every tenant retired earlier must have completed
        // its drain by now (each loop iteration closed ≥ 1 boundary).
        let report = engine.finish();
        for &t in &retired {
            let rec = report
                .reconciliations
                .iter()
                .find(|r| r.tenant == t)
                .unwrap_or_else(|| panic!("tenant {t} never reconciled"));
            // Per-tenant exactness: the reconciled bill is the fold of
            // the tenant's own epoch bills up to the reconciliation.
            let (s, m) = fold_bills_until(&report, t, rec.at);
            assert_eq!(rec.storage_dollars, s, "tenant {t} storage fold");
            assert_eq!(rec.miss_dollars, m, "tenant {t} miss fold");
            assert_eq!(rec.total_dollars, s + m, "tenant {t} total fold");
        }
        // Cluster-wide exactness: Σ per-epoch tenant bills == total
        // cluster bill, bit for bit.
        let (s, m) = fold_bills(&report, None);
        assert_eq!(s + m, report.total_cost, "Σ tenant bills != cluster bill");
        // The storage/miss splits agree too.
        assert_eq!(s, report.storage_cost, "storage fold != storage total");
        assert_eq!(m, report.miss_cost, "miss fold != miss total");
    });
}

/// Per-tenant fold of the epoch bills with `t <= until` (a retired
/// tenant's reconciliation snapshots its ledger at the drain boundary;
/// later epochs may still bill its stray traffic).
fn fold_bills_until(report: &RunReport, tenant: TenantId, until: u64) -> (f64, f64) {
    let (mut s, mut m) = (0.0, 0.0);
    for b in &report.tenant_bills {
        if b.tenant == tenant && b.t <= until {
            s += b.storage;
            m += b.miss;
        }
    }
    (s, m)
}

#[test]
fn churn_billing_is_exact_under_shared_placement() {
    exercise(PlacementKind::Shared, false, 0xC1);
    exercise(PlacementKind::Shared, true, 0xC2);
}

#[test]
fn churn_billing_is_exact_under_pinned_placement() {
    exercise(PlacementKind::HashSlotPinned, false, 0xC3);
    exercise(PlacementKind::HashSlotPinned, true, 0xC4);
}

#[test]
fn churn_billing_is_exact_under_partitioned_placement() {
    exercise(PlacementKind::SlabPartition, false, 0xC5);
    exercise(PlacementKind::SlabPartition, true, 0xC6);
}
