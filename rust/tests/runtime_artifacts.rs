//! Integration tests over the AOT artifacts: load the HLO text on the
//! PJRT CPU client, execute, and compare against the Rust oracle and the
//! paper's eq. (4) limits. Skipped (with a message) when `make artifacts`
//! has not run.

use elastictl::config::Config;
use elastictl::runtime::{
    artifacts_dir, reference_curves, BucketedStats, CostCurveModel, Manifest, Planner,
};
use elastictl::util::rng::Pcg;

fn artifacts_available() -> bool {
    Manifest::load(artifacts_dir()).is_ok()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts missing; run `make artifacts`");
            return;
        }
    };
}

fn random_inputs(n: usize, g: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg::seed_from_u64(seed);
    let lam: Vec<f32> = (0..n).map(|_| rng.range_f64(1e-6, 5.0) as f32).collect();
    let m = vec![1.4676e-7f32; n];
    let s: Vec<f32> = (0..n).map(|_| rng.range_f64(64.0, 1e7) as f32).collect();
    let c: Vec<f32> = s.iter().map(|x| x * 8.5085e-15).collect();
    let w: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 100.0) as f32).collect();
    let t: Vec<f32> = (0..g).map(|i| i as f32 * 7200.0 / g as f32).collect();
    (lam, m, c, s, w, t)
}

#[test]
fn every_manifest_variant_loads_and_matches_oracle() {
    require_artifacts!();
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    assert!(!manifest.artifacts.is_empty());
    for spec in &manifest.artifacts {
        let model = CostCurveModel::load(&dir, Some(spec.n)).unwrap();
        assert_eq!(model.n, spec.n);
        assert_eq!(model.g, spec.g);
        let (lam, m, c, s, w, t) = random_inputs(spec.n, spec.g, spec.n as u64);
        let got = model.evaluate(&lam, &m, &c, &s, &w, &t).unwrap();
        let want = reference_curves(&lam, &m, &c, &s, &w, &t);
        for (name, a, b) in [
            ("cost", &got.cost, &want.cost),
            ("vsize", &got.vsize, &want.vsize),
            ("missrate", &got.missrate, &want.missrate),
        ] {
            assert_eq!(a.len(), spec.g);
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                let denom = y.abs().max(1e-20);
                assert!(
                    ((x - y) / denom).abs() < 1e-3,
                    "{name}[{i}] (n={}): pjrt={x} oracle={y}",
                    spec.n
                );
            }
        }
    }
}

#[test]
fn artifact_respects_eq4_limits() {
    require_artifacts!();
    let model = CostCurveModel::load(artifacts_dir(), None).unwrap();
    let (lam, m, c, s, w, mut t) = random_inputs(model.n, model.g, 99);
    // First half of the grid at T=0, second at T≈∞.
    for (i, v) in t.iter_mut().enumerate() {
        *v = if i < model.g / 2 { 0.0 } else { 1e9 };
    }
    let got = model.evaluate(&lam, &m, &c, &s, &w, &t).unwrap();
    let all_miss: f32 = lam.iter().zip(&m).zip(&w).map(|((l, mm), ww)| ww * l * mm).sum();
    let all_store: f32 = c.iter().zip(&w).map(|(cc, ww)| ww * cc).sum();
    assert!(((got.cost[0] - all_miss) / all_miss).abs() < 1e-3);
    let last = got.cost[model.g - 1];
    assert!(((last - all_store) / all_store).abs() < 1e-2, "last={last} store={all_store}");
    assert!(got.vsize[0].abs() < 1.0);
}

#[test]
fn planner_uses_artifact_and_agrees_with_oracle_planner() {
    require_artifacts!();
    let cfg = Config::default();
    let planner = Planner::load(artifacts_dir(), cfg.controller.t_max_secs);
    assert!(planner.uses_artifact(), "planner fell back to oracle");

    let mut rng = Pcg::seed_from_u64(5);
    let items: Vec<(u32, u32)> = (0..20_000)
        .map(|i| {
            (
                (10_000 / (i + 1)).max(1) as u32,
                (64 + rng.below(5_000_000)) as u32,
            )
        })
        .collect();
    let stats = BucketedStats::build(&items, planner.n_buckets(), 3600.0, &cfg.cost);
    let plan = planner.plan(&stats, cfg.cost.instance.ram_bytes).unwrap();

    let oracle = Planner::oracle(planner.n_buckets(), 256, cfg.controller.t_max_secs);
    let oracle_plan = oracle.plan(&stats, cfg.cost.instance.ram_bytes).unwrap();
    // Same bucketing, same grid resolution → same optimum (modulo fp).
    assert!(
        (plan.t_star_secs - oracle_plan.t_star_secs).abs()
            <= 0.05 * (plan.t_star_secs + oracle_plan.t_star_secs + 1.0),
        "pjrt T*={} oracle T*={}",
        plan.t_star_secs,
        oracle_plan.t_star_secs
    );
    assert_eq!(plan.instances, oracle_plan.instances);
}

#[test]
fn analytic_sizer_runs_a_full_simulation() {
    require_artifacts!();
    use elastictl::runtime::AnalyticSizer;
    use elastictl::sim::run_policy;
    use elastictl::trace::{SynthConfig, SynthGenerator, VecSource};

    let mut cfg = Config::default();
    cfg.cost.instance.ram_bytes = 40_000_000;
    cfg.cost.instance.dollars_per_hour = 0.017 * 40.0e6 / 555.0e6;
    cfg.cost.epoch_us = 10 * elastictl::MINUTE;
    let mut synth = SynthConfig::tiny();
    synth.mean_rate = 150.0;
    let trace = SynthGenerator::new(synth).generate();

    let sizer = Box::new(AnalyticSizer::from_config(&cfg));
    let res = run_policy(&cfg, &mut VecSource::new(trace), sizer, 1);
    assert_eq!(res.policy, "analytic");
    assert!(res.requests > 10_000);
    assert!(res.total_cost > 0.0);
    assert!(res.miss_ratio() < 1.0);
}
