//! Integration tests for the concurrent server runtime ([`elastictl::srv`]):
//! a trace replayed over 4 connections must leave the engine in exactly
//! the state a single-connection replay leaves it in (the state thread
//! serializes all engine access), and a kill + `--resume` cycle must
//! reproduce the uninterrupted run's cumulative bills bit for bit.

use elastictl::config::{Config, PolicyKind};
use elastictl::srv::{accept_loop, checkpoint, loadgen, spawn_state, Server};
use elastictl::trace::Request;
use elastictl::util::tempdir::tempdir;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;

fn fixed_cfg() -> Config {
    let mut cfg = Config::with_policy(PolicyKind::Fixed);
    cfg.scaler.fixed_instances = 2;
    cfg
}

/// Bind an ephemeral port, spawn the state thread (optionally resuming
/// from `ckpt`) and the accept loop; return the address and the server.
fn start(cfg: Config, ckpt: Option<PathBuf>) -> (String, Server) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = spawn_state(cfg, ckpt).unwrap();
    let tx = server.tx.clone();
    std::thread::spawn(move || {
        let _ = accept_loop(listener, tx);
    });
    (addr, server)
}

/// One ad-hoc protocol round trip over TCP (for EPOCH / STATS).
fn roundtrip(addr: &str, line: &str) -> String {
    let mut sock = TcpStream::connect(addr).unwrap();
    sock.write_all(format!("{line}\nQUIT\n").as_bytes()).unwrap();
    let mut lines = BufReader::new(sock).lines();
    lines.next().unwrap().unwrap()
}

/// Uniform-size single-tenant trace: FP miss-cost sums are then
/// identical in every accumulation order, so cumulative totals compare
/// bit for bit across connection counts.
fn trace(objs: std::ops::Range<u64>, repeats: u64) -> Vec<Request> {
    let mut reqs = Vec::new();
    let mut ts = 0;
    for _ in 0..repeats {
        for obj in objs.clone() {
            reqs.push(Request::new(ts, obj, 1000));
            ts += 1000;
        }
    }
    reqs
}

#[test]
fn four_connections_equal_one_connection() {
    let reqs = trace(0..50, 4); // 200 requests, 50 distinct objects

    let (addr4, srv4) = start(fixed_cfg(), None);
    let report = loadgen::run(&addr4, &reqs, 4).unwrap();
    assert_eq!(report.connections, 4);
    assert_eq!(report.requests, 200);
    assert_eq!(report.hits, 150, "50 distinct objects -> 50 misses");
    assert!(report.requests_per_sec() > 0.0);
    assert!(report.p50_us <= report.p99_us);

    let (addr1, srv1) = start(fixed_cfg(), None);
    let single = loadgen::run(&addr1, &reqs, 1).unwrap();
    assert_eq!(single.hits, report.hits);

    // The full STATS line — requests, misses, spurious, miss_ratio,
    // instances, cumulative miss dollars — must agree exactly.
    let s4 = roundtrip(&addr4, "STATS");
    let s1 = roundtrip(&addr1, "STATS");
    assert!(s4.contains("\"requests\":200"), "{s4}");
    assert_eq!(s4, s1, "concurrent replay must match single-connection state");
    drop(srv4);
    drop(srv1);
}

#[test]
fn kill_and_resume_over_tcp_is_bit_identical() {
    let dir = tempdir().unwrap();
    let interrupted = dir.path().join("interrupted.ckpt");
    let baseline = dir.path().join("baseline.ckpt");
    // Disjoint fresh key ranges per segment: the resumed (cold-cache)
    // server misses exactly like the uninterrupted one.
    let seg1 = trace(0..40, 1);
    let seg2 = trace(100..140, 1);

    // Baseline: both segments through one server, same epoch boundaries
    // the interrupted run will have.
    let (addr_b, srv_b) = start(fixed_cfg(), Some(baseline.clone()));
    loadgen::run(&addr_b, &seg1, 4).unwrap();
    assert!(roundtrip(&addr_b, "EPOCH").starts_with("RESIZED"));
    loadgen::run(&addr_b, &seg2, 4).unwrap();
    assert!(roundtrip(&addr_b, "EPOCH").starts_with("RESIZED"));
    drop(srv_b);

    // Interrupted: segment 1 and one epoch, then the server is simply
    // abandoned — every closed epoch is already fsync'd, so there is
    // nothing graceful left to do (that is the point).
    let (addr_1, srv_1) = start(fixed_cfg(), Some(interrupted.clone()));
    loadgen::run(&addr_1, &seg1, 4).unwrap();
    assert!(roundtrip(&addr_1, "EPOCH").starts_with("RESIZED"));
    drop(srv_1);

    // Resume from the checkpoint on a fresh port and finish.
    let (addr_2, srv_2) = start(fixed_cfg(), Some(interrupted.clone()));
    assert_eq!(srv_2.resumed_epochs, 1, "one closed epoch must be restored");
    loadgen::run(&addr_2, &seg2, 4).unwrap();
    assert!(roundtrip(&addr_2, "EPOCH").starts_with("RESIZED"));
    drop(srv_2);

    // The durable bills agree bit for bit (epoch timestamps are wall
    // clock and legitimately differ; the money and counts must not).
    let last = |p: &std::path::Path| checkpoint::read(p).unwrap().pop().unwrap();
    let (a, b) = (last(&interrupted), last(&baseline));
    assert_eq!((a.epoch, b.epoch), (2, 2));
    assert_eq!(a.cum_miss_dollars, b.cum_miss_dollars, "bit-identical miss dollars");
    assert_eq!(a.cum_storage_dollars, b.cum_storage_dollars, "bit-identical storage");
    assert_eq!(a.ledgers, b.ledgers, "bit-identical per-tenant ledgers");
    assert_eq!(a.costs.miss_count, b.costs.miss_count);
    assert_eq!(
        a.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
        b.bills.iter().map(|x| (x.tenant, x.storage, x.miss)).collect::<Vec<_>>(),
        "bit-identical final-epoch bill rows"
    );
}
