//! Property-style round-trip tests for trace IO: arbitrary request
//! streams — including max-size, zero-timestamp and extreme-tenant edge
//! cases — must survive `write_trace`/`read_trace` and
//! `write_csv`/`read_csv` bit-for-bit, legacy v1/tenant-less files must
//! keep loading as tenant 0, and arbitrary evented (v3) item streams
//! must survive the tagged-row CSV lane
//! (`write_items_csv`/`read_items_csv`) with request-only readers
//! skipping the events.

use elastictl::trace::{
    read_csv, read_items_csv, read_trace, write_csv, write_items_csv, write_trace, Request,
    TenantEvent, TraceItem,
};
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::util::tempdir::tempdir;

/// Draw an arbitrary request, biased toward the edges of every field.
fn arb_request(rng: &mut Pcg, monotone_ts: &mut u64) -> Request {
    let ts = match rng.below(8) {
        0 => 0,
        1 => u64::MAX - rng.below(1000),
        _ => {
            *monotone_ts += rng.below(10_000_000);
            *monotone_ts
        }
    };
    let obj = match rng.below(4) {
        0 => 0,
        1 => u64::MAX,
        _ => rng.next_u64(),
    };
    let size = match rng.below(4) {
        0 => 0,
        1 => u32::MAX,
        _ => rng.below(1 << 32) as u32,
    };
    let tenant = match rng.below(4) {
        0 => 0,
        1 => u16::MAX,
        _ => rng.below(1 << 16) as u16,
    };
    Request { ts, obj, size, tenant }
}

fn arb_trace(rng: &mut Pcg) -> Vec<Request> {
    let len = rng.below_usize(300);
    let mut ts = 0u64;
    (0..len).map(|_| arb_request(rng, &mut ts)).collect()
}

#[test]
fn prop_binary_round_trip_preserves_requests() {
    check("trace_binary_round_trip", 0x7B1, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("t.bin");
        let reqs = arb_trace(rng);
        let n = write_trace(&p, &reqs).unwrap();
        assert_eq!(n, reqs.len() as u64);
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

#[test]
fn prop_csv_round_trip_preserves_requests() {
    check("trace_csv_round_trip", 0xC5B, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = arb_trace(rng);
        write_csv(&p, &reqs).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

/// Draw an arbitrary tenant lifecycle event, biased toward field edges.
fn arb_event(rng: &mut Pcg, ts: u64) -> TenantEvent {
    let tenant = match rng.below(4) {
        0 => 0,
        1 => u16::MAX,
        _ => rng.below(1 << 16) as u16,
    };
    if rng.below(3) == 0 {
        return TenantEvent::retire(ts, tenant);
    }
    let reserved = match rng.below(4) {
        0 => 0,
        1 => u64::MAX,
        _ => rng.next_u64(),
    };
    // Any finite f64 round-trips exactly through shortest-repr Display.
    let multiplier = match rng.below(4) {
        0 => 0.0,
        1 => f64::MAX,
        _ => rng.next_u64() as f64 / 1e9,
    };
    let mut ev = TenantEvent::admit(ts, tenant)
        .with_reserved_bytes(reserved)
        .with_multiplier(multiplier);
    if rng.below(2) == 0 {
        ev = ev.with_slo_miss_ratio(rng.below(1 << 20) as f64 / (1 << 20) as f64);
    }
    ev
}

#[test]
fn prop_csv_event_lane_round_trips_items() {
    check("trace_csv_event_lane", 0xE7A, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("churn.csv");
        let len = rng.below_usize(200);
        let mut ts = 0u64;
        let items: Vec<TraceItem> = (0..len)
            .map(|_| {
                if rng.below(4) == 0 {
                    let ets = rng.below(1 << 40);
                    TraceItem::Event(arb_event(rng, ets))
                } else {
                    TraceItem::Request(arb_request(rng, &mut ts))
                }
            })
            .collect();
        write_items_csv(&p, &items).unwrap();
        assert_eq!(read_items_csv(&p).unwrap(), items);
        // A request-only reader of the same file sees just the requests.
        let reqs: Vec<Request> = items
            .iter()
            .filter_map(|i| match i {
                TraceItem::Request(r) => Some(*r),
                TraceItem::Event(_) => None,
            })
            .collect();
        assert_eq!(read_csv(&p).unwrap(), reqs);
    });
}

#[test]
fn prop_legacy_csv_loads_as_tenant_zero() {
    check("trace_legacy_csv", 0x1E6, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("legacy.csv");
        let mut reqs = arb_trace(rng);
        for r in &mut reqs {
            r.tenant = 0;
        }
        // Write the pre-tenant three-column format by hand.
        let mut text = String::from("ts_us,obj,size\n");
        for r in &reqs {
            text.push_str(&format!("{},{},{}\n", r.ts, r.obj, r.size));
        }
        std::fs::write(&p, text).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

#[test]
fn prop_legacy_v1_binary_loads_as_tenant_zero() {
    check("trace_legacy_v1_binary", 0x1E7, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("legacy.bin");
        let mut reqs = arb_trace(rng);
        for r in &mut reqs {
            r.tenant = 0;
        }
        // Write the 20-byte v1 record format by hand.
        let mut bytes = Vec::with_capacity(16 + reqs.len() * 20);
        bytes.extend_from_slice(b"ELTC");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(reqs.len() as u64).to_le_bytes());
        for r in &reqs {
            bytes.extend_from_slice(&r.ts.to_le_bytes());
            bytes.extend_from_slice(&r.obj.to_le_bytes());
            bytes.extend_from_slice(&r.size.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    });
}
