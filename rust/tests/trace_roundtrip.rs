//! Property-style round-trip tests for trace IO: arbitrary request
//! streams — including max-size, zero-timestamp and extreme-tenant edge
//! cases — must survive `write_trace`/`read_trace` and
//! `write_csv`/`read_csv` bit-for-bit, legacy v1/tenant-less files must
//! keep loading as tenant 0, and arbitrary evented (v3) item streams
//! must survive the tagged-row CSV lane
//! (`write_items_csv`/`read_items_csv`) with request-only readers
//! skipping the events.

use elastictl::trace::{
    read_csv, read_items, read_items_csv, read_trace, write_csv, write_items, write_items_csv,
    write_trace, CsvReader, Request, RequestSource, TenantEvent, TraceItem, TraceReader,
};
use elastictl::util::proptest::check;
use elastictl::util::rng::Pcg;
use elastictl::util::tempdir::tempdir;

/// Draw an arbitrary request, biased toward the edges of every field.
fn arb_request(rng: &mut Pcg, monotone_ts: &mut u64) -> Request {
    let ts = match rng.below(8) {
        0 => 0,
        1 => u64::MAX - rng.below(1000),
        _ => {
            *monotone_ts += rng.below(10_000_000);
            *monotone_ts
        }
    };
    let obj = match rng.below(4) {
        0 => 0,
        1 => u64::MAX,
        _ => rng.next_u64(),
    };
    let size = match rng.below(4) {
        0 => 0,
        1 => u32::MAX,
        _ => rng.below(1 << 32) as u32,
    };
    let tenant = match rng.below(4) {
        0 => 0,
        1 => u16::MAX,
        _ => rng.below(1 << 16) as u16,
    };
    Request { ts, obj, size, tenant }
}

fn arb_trace(rng: &mut Pcg) -> Vec<Request> {
    let len = rng.below_usize(300);
    let mut ts = 0u64;
    (0..len).map(|_| arb_request(rng, &mut ts)).collect()
}

#[test]
fn prop_binary_round_trip_preserves_requests() {
    check("trace_binary_round_trip", 0x7B1, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("t.bin");
        let reqs = arb_trace(rng);
        let n = write_trace(&p, &reqs).unwrap();
        assert_eq!(n, reqs.len() as u64);
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

#[test]
fn prop_csv_round_trip_preserves_requests() {
    check("trace_csv_round_trip", 0xC5B, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("t.csv");
        let reqs = arb_trace(rng);
        write_csv(&p, &reqs).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

/// Draw an arbitrary tenant lifecycle event, biased toward field edges.
fn arb_event(rng: &mut Pcg, ts: u64) -> TenantEvent {
    let tenant = match rng.below(4) {
        0 => 0,
        1 => u16::MAX,
        _ => rng.below(1 << 16) as u16,
    };
    if rng.below(3) == 0 {
        return TenantEvent::retire(ts, tenant);
    }
    let reserved = match rng.below(4) {
        0 => 0,
        1 => u64::MAX,
        _ => rng.next_u64(),
    };
    // Any finite f64 round-trips exactly through shortest-repr Display.
    let multiplier = match rng.below(4) {
        0 => 0.0,
        1 => f64::MAX,
        _ => rng.next_u64() as f64 / 1e9,
    };
    let mut ev = TenantEvent::admit(ts, tenant)
        .with_reserved_bytes(reserved)
        .with_multiplier(multiplier);
    if rng.below(2) == 0 {
        ev = ev.with_slo_miss_ratio(rng.below(1 << 20) as f64 / (1 << 20) as f64);
    }
    ev
}

#[test]
fn prop_csv_event_lane_round_trips_items() {
    check("trace_csv_event_lane", 0xE7A, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("churn.csv");
        let len = rng.below_usize(200);
        let mut ts = 0u64;
        let items: Vec<TraceItem> = (0..len)
            .map(|_| {
                if rng.below(4) == 0 {
                    let ets = rng.below(1 << 40);
                    TraceItem::Event(arb_event(rng, ets))
                } else {
                    TraceItem::Request(arb_request(rng, &mut ts))
                }
            })
            .collect();
        write_items_csv(&p, &items).unwrap();
        assert_eq!(read_items_csv(&p).unwrap(), items);
        // A request-only reader of the same file sees just the requests.
        let reqs: Vec<Request> = items
            .iter()
            .filter_map(|i| match i {
                TraceItem::Request(r) => Some(*r),
                TraceItem::Event(_) => None,
            })
            .collect();
        assert_eq!(read_csv(&p).unwrap(), reqs);
    });
}

/// An arbitrary mixed v3 item stream (requests + lifecycle events),
/// never empty — the malformed-input properties need something to tear.
fn arb_items(rng: &mut Pcg) -> Vec<TraceItem> {
    let len = 1 + rng.below_usize(100);
    let mut ts = 0u64;
    (0..len)
        .map(|_| {
            if rng.below(4) == 0 {
                let ets = rng.below(1 << 40);
                TraceItem::Event(arb_event(rng, ets))
            } else {
                TraceItem::Request(arb_request(rng, &mut ts))
            }
        })
        .collect()
}

/// On-disk length of one v3 tagged record: 1 tag byte + 22 (request),
/// 34 (admit) or 10 (retire) payload bytes.
fn v3_record_len(item: &TraceItem) -> usize {
    match item {
        TraceItem::Request(_) => 1 + 22,
        TraceItem::Event(e) => {
            if e.spec().is_some() {
                1 + 34
            } else {
                1 + 10
            }
        }
    }
}

/// Torn v3 tails: chopping the file at ANY byte short of its full length
/// (the header's item count still promising the original stream) must
/// yield a clean prefix of the items, a terminated stream, and a
/// truncation error out of `check()` — never a silent short read.
#[test]
fn prop_torn_v3_binary_tail_surfaces_check_error() {
    check("trace_torn_v3_tail", 0xF3A, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("torn.bin");
        let items = arb_items(rng);
        write_items(&p, &items).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let cut = 16 + rng.below_usize(bytes.len() - 16);
        std::fs::write(&p, &bytes[..cut]).unwrap();

        let mut r = TraceReader::open(&p).unwrap();
        let mut got = Vec::new();
        while let Some(item) = r.next_item() {
            got.push(item);
        }
        assert!(got.len() < items.len(), "a torn tail must lose at least one item");
        assert_eq!(got[..], items[..got.len()], "surviving prefix must be intact");
        let err = r.check().expect_err("truncation must be reported");
        assert!(err.to_string().contains("truncated"), "{err}");
        // The batch reader refuses the same file outright.
        assert!(read_items(&p).is_err());
    });
}

/// Garbage record tags anywhere in a v3 stream are corruption: the
/// reader stops at the flipped record, hands back the intact prefix, and
/// `check()` names the bad tag.
#[test]
fn prop_garbage_v3_tag_surfaces_check_error() {
    check("trace_garbage_v3_tag", 0xF3B, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("flip.bin");
        let items = arb_items(rng);
        write_items(&p, &items).unwrap();
        let k = rng.below_usize(items.len());
        let offset = 16 + items[..k].iter().map(v3_record_len).sum::<usize>();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[offset] = 3 + (rng.below(253) as u8); // any tag outside {0,1,2}
        std::fs::write(&p, &bytes).unwrap();

        let mut r = TraceReader::open(&p).unwrap();
        let mut got = Vec::new();
        while let Some(item) = r.next_item() {
            got.push(item);
        }
        assert_eq!(got[..], items[..k], "items before the flipped tag must survive");
        let err = r.check().expect_err("a garbage tag must be reported");
        assert!(err.to_string().contains("tag"), "{err}");
        assert!(read_items(&p).is_err());
    });
}

/// Malformed CSV rows — truncated request rows, event rows with missing
/// or non-numeric fields, stray tags — spliced at a random position into
/// a valid event-lane file must stop the stream there and surface a
/// `check()` error; the rows above the splice still parse.
#[test]
fn prop_malformed_csv_rows_surface_check_error() {
    const BAD_ROWS: &[&str] = &[
        "ADMIT,1,2,3,4",          // admit row missing the slo field
        "RETIRE,7",               // retire row missing the tenant
        "ADMIT,5,6,xx,1.0,-",     // non-numeric reserved_bytes
        "RETIRE,a,b",             // non-numeric ts
        "ADMIT,1,2,3,4,zz",       // unparsable slo
        "9999,123",               // truncated request row
        "nope,2,3,4",             // non-numeric ts on a request row
        ",,,,",                   // all fields empty
        "FOO,1,2,3",              // stray tag parses as a request row
    ];
    check("trace_malformed_csv_rows", 0xF3C, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("bad.csv");
        let items = arb_items(rng);
        write_items_csv(&p, &items).unwrap();
        // Splice one bad row at a random data position.
        let pos = rng.below_usize(items.len() + 1);
        let bad = BAD_ROWS[rng.below_usize(BAD_ROWS.len())];
        let text = std::fs::read_to_string(&p).unwrap();
        let mut lines: Vec<&str> = text.lines().collect();
        lines.insert(1 + pos, bad); // line 0 is the header
        std::fs::write(&p, lines.join("\n")).unwrap();

        let mut r = CsvReader::open(&p).unwrap();
        let mut got = Vec::new();
        while let Some(item) = r.next_item() {
            got.push(item);
        }
        assert_eq!(got[..], items[..pos], "rows above the splice must survive ({bad})");
        assert!(r.check().is_err(), "{bad} must be reported");
        assert!(read_items_csv(&p).is_err(), "{bad} must fail the batch reader");
    });
}

#[test]
fn prop_legacy_csv_loads_as_tenant_zero() {
    check("trace_legacy_csv", 0x1E6, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("legacy.csv");
        let mut reqs = arb_trace(rng);
        for r in &mut reqs {
            r.tenant = 0;
        }
        // Write the pre-tenant three-column format by hand.
        let mut text = String::from("ts_us,obj,size\n");
        for r in &reqs {
            text.push_str(&format!("{},{},{}\n", r.ts, r.obj, r.size));
        }
        std::fs::write(&p, text).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back, reqs);
    });
}

#[test]
fn prop_legacy_v1_binary_loads_as_tenant_zero() {
    check("trace_legacy_v1_binary", 0x1E7, |rng| {
        let dir = tempdir().unwrap();
        let p = dir.path().join("legacy.bin");
        let mut reqs = arb_trace(rng);
        for r in &mut reqs {
            r.tenant = 0;
        }
        // Write the 20-byte v1 record format by hand.
        let mut bytes = Vec::with_capacity(16 + reqs.len() * 20);
        bytes.extend_from_slice(b"ELTC");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(reqs.len() as u64).to_le_bytes());
        for r in &reqs {
            bytes.extend_from_slice(&r.ts.to_le_bytes());
            bytes.extend_from_slice(&r.obj.to_le_bytes());
            bytes.extend_from_slice(&r.size.to_le_bytes());
        }
        std::fs::write(&p, bytes).unwrap();
        let back = read_trace(&p).unwrap();
        assert_eq!(back, reqs);
    });
}
