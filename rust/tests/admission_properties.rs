//! Property suite for the admission filters (ISSUE 10 satellite): the
//! Mth-request sketch's one-sided error (never admits *later* than the
//! true Mth request), its bounded false-admit rate under adversarial key
//! sets, exact epoch halving, constant state size, and the keep/drop
//! filter's cost inequality.

use elastictl::admission::{AdmissionFilter, KeepCostFilter, MthRequestFilter, SKETCH_COUNTER_MAX};
use elastictl::config::CostConfig;
use elastictl::trace::Request;
use elastictl::util::proptest::check;
use std::collections::HashMap;

fn req(tenant: u16, obj: u64) -> Request {
    Request::new(0, obj, 1000).with_tenant(tenant)
}

/// The sketch is depth-1 with saturating increments: a key's cell count
/// is at least `min(true observations, 15)`, so whenever the true count
/// reaches M the filter must already admit. Collisions may admit early,
/// never late.
#[test]
fn sketch_never_admits_later_than_the_true_mth_request() {
    check("mth_never_late", 0xAD_01, |rng| {
        let m = 1 + rng.below(SKETCH_COUNTER_MAX as u64) as u32;
        let mut f = MthRequestFilter::new(1 << 12, m);
        // A small, hot key pool so every key accumulates observations.
        let pool: Vec<(u16, u64)> = (0..64)
            .map(|_| (rng.below(4) as u16, rng.next_u64() >> 20))
            .collect();
        let mut truth: HashMap<(u16, u64), u32> = HashMap::new();
        for _ in 0..2_000 {
            let (t, o) = pool[rng.below_usize(pool.len())];
            let n = truth.entry((t, o)).or_insert(0);
            *n += 1;
            let admitted = f.observe(&req(t, o), None);
            if *n >= m {
                assert!(
                    admitted,
                    "true count {n} ≥ M={m} but the filter refused (t={t} o={o})"
                );
            }
            // The cell never under-counts the key's own observations.
            let expect = (*n).min(SKETCH_COUNTER_MAX as u32) as u8;
            assert!(
                f.count(t, o) >= expect,
                "cell {} under-counts true {expect}",
                f.count(t, o)
            );
        }
    });
}

/// False admits come only from cell collisions, so on a fresh key the
/// first-observation admit rate is bounded by the sketch's load factor.
/// Preload ⅛ of the cells (both sequential-id and random-id key sets —
/// sequential is the classic adversarial pattern for weak hashes), then
/// probe never-seen keys: well under 20% may slip through at M=2.
#[test]
fn false_admit_rate_stays_under_the_load_factor_bound() {
    check("mth_false_admits", 0xAD_02, |rng| {
        let mut f = MthRequestFilter::new(1 << 15, 2);
        let cells = f.cell_count() as u64; // 65536
        let preload = cells / 8;
        let sequential = rng.chance(0.5);
        let base = rng.next_u64() >> 20;
        for i in 0..preload {
            let obj = if sequential { base + i } else { rng.next_u64() >> 4 };
            f.observe(&req(0, obj), None);
        }
        // Fresh keys from a disjoint id range (tenant 1 scopes them away
        // from every preloaded key even on draw collisions).
        let probes = 2_000u64;
        let mut admitted = 0u64;
        for i in 0..probes {
            if f.observe(&req(1, (1 << 60) | (base + i)), None) {
                admitted += 1;
            }
        }
        let rate = admitted as f64 / probes as f64;
        assert!(
            rate <= 0.20,
            "false-admit rate {rate:.3} exceeds bound (load {:.3})",
            preload as f64 / cells as f64
        );
    });
}

/// Epoch aging halves every counter exactly (floor), whatever the count.
#[test]
fn epoch_aging_halves_counts_exactly() {
    check("mth_aging", 0xAD_03, |rng| {
        // M=15 keeps the gate irrelevant; we only exercise the counters.
        let mut f = MthRequestFilter::new(1 << 13, 15);
        let keys: Vec<(u16, u64)> = (0..50)
            .map(|_| (rng.below(8) as u16, rng.next_u64() >> 8))
            .collect();
        for &(t, o) in &keys {
            for _ in 0..rng.below(20) {
                f.observe(&req(t, o), None);
            }
        }
        // Snapshot *cell* reads (collisions included) before aging: the
        // halving contract is per cell, floor division.
        let before: Vec<u8> = keys.iter().map(|&(t, o)| f.count(t, o)).collect();
        f.end_epoch();
        for (&(t, o), &b) in keys.iter().zip(&before) {
            assert_eq!(f.count(t, o), b / 2, "cell for ({t},{o}) was {b}");
        }
    });
}

/// Filter state is exactly the configured sketch allocation (rounded up
/// to a power of two) and never grows, however many unique keys stream
/// through.
#[test]
fn sketch_state_is_constant_in_unique_key_count() {
    check("mth_state_bytes", 0xAD_04, |rng| {
        let asked = 1usize << (10 + rng.below(6)); // 1 KB .. 32 KB
        let mut f = MthRequestFilter::new(asked, 2);
        let allocated = f.state_bytes();
        assert_eq!(allocated, asked.next_power_of_two());
        assert_eq!(f.cell_count(), allocated * 2);
        let base = rng.next_u64() >> 20;
        for i in 0..20_000u64 {
            f.observe(&req((i % 5) as u16, base + i), None);
        }
        assert_eq!(f.state_bytes(), allocated, "state grew with unique keys");
    });
}

/// 200k unique keys through the default-size sketch: the footprint
/// stays at the configured bytes (the fixed-size guarantee at scale).
#[test]
fn sketch_state_survives_two_hundred_thousand_unique_keys() {
    let mut f = MthRequestFilter::new(32_768, 2);
    let allocated = f.state_bytes();
    for i in 0..200_000u64 {
        f.observe(&req(0, (7 << 40) + i), None);
    }
    assert_eq!(f.state_bytes(), allocated);
}

/// keep_cost admits iff expected miss dollars ≥ threshold × expected
/// storage dollars over the tenant's current TTL, computed here from
/// the cost catalog independently of the filter's own arithmetic; a
/// missing timer leaves the filter inert (admit).
#[test]
fn keep_cost_admits_iff_miss_dollars_cover_storage_dollars() {
    check("keep_cost_inequality", 0xAD_05, |rng| {
        let mut cost = CostConfig::default();
        cost.miss_cost_dollars = rng.range_f64(1e-9, 1e-4);
        let threshold = rng.range_f64(0.1, 8.0);
        let multiplier = rng.range_f64(0.25, 4.0);
        let size = rng.range_u64(100, 10_000_000) as u32;
        let ttl = rng.range_f64(0.5, 500_000.0);
        let mut f = KeepCostFilter::new(cost.clone(), threshold);
        f.set_multiplier(2, multiplier);
        let r = Request::new(0, 1, size).with_tenant(2);
        let miss = multiplier * cost.miss_cost(size);
        let storage = size as f64 * cost.storage_cost_per_byte_sec() * ttl;
        // Skip knife-edge draws: the contract is the inequality, not a
        // particular rounding of float noise at exact equality.
        if (miss - threshold * storage).abs() <= 1e-9 * miss.max(threshold * storage) {
            return;
        }
        let expect = miss >= threshold * storage;
        assert_eq!(f.observe(&r, Some(ttl)), expect, "size={size} ttl={ttl}");
        // Shrinking the TTL only shrinks the storage side: an admitted
        // object stays admitted at any shorter timer.
        if expect {
            assert!(f.observe(&r, Some(ttl * 0.25)));
        }
        assert!(f.observe(&r, None), "no timer ⇒ inert");
    });
}
