#!/usr/bin/env bash
# CI gate: formatting, lints, release build, tests.
#
# Usage: ./ci.sh            (from anywhere; operates on the repo checkout)
# Env:   ELASTICTL_PROPTEST_CASES / ELASTICTL_BENCH_QUICK are honored by
#        the test suite; CI keeps their defaults.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo fmt --check"
cargo fmt --all --check || {
    echo "ci: formatting drift detected (run 'cargo fmt --all')" >&2
    exit 1
}

echo "==> cargo clippy (all targets, -D warnings)"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "ci: all green"
