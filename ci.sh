#!/usr/bin/env bash
# CI gate: formatting, lints, release build, tests.
#
# Usage: ./ci.sh            (from anywhere; operates on the repo checkout)
# Env:   ELASTICTL_PROPTEST_CASES / ELASTICTL_BENCH_QUICK are honored by
#        the test suite; CI keeps their defaults. ELASTICTL_TEST_SHARDS=N
#        narrows the sharded parity/property suites to one shard width
#        (the CI shards matrix leg runs the whole gate at 4).
#
# Reproducibility: every cargo invocation runs --locked against
# Cargo.lock so CI cannot silently drift to a newer dependency
# resolution. If no lockfile exists yet it is generated first; in a
# fully offline environment where that is impossible, the gate falls
# back to unlocked resolution with a loud note rather than failing.
set -euo pipefail
cd "$(dirname "$0")"

LOCKED="--locked"
if [[ ! -f Cargo.lock ]]; then
    if cargo generate-lockfile 2>/dev/null; then
        echo "ci: generated Cargo.lock (consider committing it)"
    else
        echo "ci: WARNING no Cargo.lock and offline generation failed; running unlocked" >&2
        LOCKED=""
    fi
elif ! cargo metadata --locked --format-version 1 >/dev/null 2>&1; then
    if [[ -n "${CI:-}" ]]; then
        # On networked CI an unsatisfiable lockfile IS the drift this gate
        # exists to catch (Cargo.toml changed without regenerating the
        # lock) — fail hard instead of silently running unlocked.
        echo "ci: Cargo.lock is out of sync with Cargo.toml (run 'cargo generate-lockfile' and commit it)" >&2
        exit 1
    fi
    # Outside CI (offline/vendored environments pinning a different
    # resolution) fall back loudly rather than bricking local runs.
    echo "ci: WARNING committed Cargo.lock is not satisfiable here; running unlocked" >&2
    LOCKED=""
fi

# The fmt and clippy gates need their rustup components; probe up front
# so a missing one fails with an actionable message instead of a cryptic
# "no such command" half-way through the gate.
cargo fmt --version >/dev/null 2>&1 || {
    echo "ci: cargo fmt is unavailable (run 'rustup component add rustfmt')" >&2
    exit 1
}
cargo clippy --version >/dev/null 2>&1 || {
    echo "ci: cargo clippy is unavailable (run 'rustup component add clippy')" >&2
    exit 1
}

echo "==> cargo fmt --check"
cargo fmt --all --check || {
    echo "ci: formatting drift detected (run 'cargo fmt --all')" >&2
    exit 1
}

echo "==> cargo clippy (all targets, -D warnings, ${LOCKED:-unlocked})"
cargo clippy $LOCKED --all-targets -- -D warnings

echo "==> cargo build --release ${LOCKED:-unlocked}"
cargo build $LOCKED --release

echo "==> cargo test -q ${LOCKED:-unlocked}${ELASTICTL_TEST_SHARDS:+ (shards=$ELASTICTL_TEST_SHARDS)}"
cargo test $LOCKED -q

echo "==> cargo doc --no-deps (-D warnings, ${LOCKED:-unlocked})"
RUSTDOCFLAGS="-D warnings" cargo doc $LOCKED --no-deps

# Advisory coverage (opt-in, mirrors the CI coverage job): with
# ELASTICTL_COVERAGE=1 and cargo-llvm-cov installed, measure workspace
# line coverage and warn — never fail — when the engine/tenant/admission
# modules fall below 70%. The lcov report lands in target/lcov.info.
if [[ -n "${ELASTICTL_COVERAGE:-}" ]]; then
    if cargo llvm-cov --version >/dev/null 2>&1; then
        echo "==> cargo llvm-cov --workspace (advisory, ${LOCKED:-unlocked})"
        cargo llvm-cov $LOCKED --workspace --lcov --output-path target/lcov.info
        python3 scripts/check_coverage.py target/lcov.info --threshold 70 || true
    else
        echo "ci: NOTE cargo-llvm-cov unavailable; skipping advisory coverage (cargo install cargo-llvm-cov)" >&2
    fi
fi

echo "ci: all green"
