"""L1 — Pallas kernel for the IRM cost-curve evaluation (eq. 4).

The hot-spot is a (G x N) elementwise-exp + weighted reduction over N,
emitting three G-length curves. The kernel tiles the iteration space as

    grid = (G / BLOCK_G, N / BLOCK_N)

with per-block operands resident in VMEM:

  * lam/m/c/s/w blocks:  (BLOCK_N,)   five operands
  * t block:             (BLOCK_G,)
  * outputs:             (BLOCK_G,) accumulated across the N axis of the
                         grid (output blocks map to the G tile only, so
                         successive N steps accumulate in place — the
                         standard Pallas reduction idiom).

HARDWARE ADAPTATION (DESIGN.md §Hardware-Adaptation): the paper has no GPU
kernel; this is the paper's *analytic model* as dense compute. On a real
TPU the kernel is VPU-bound (exp + FMA, no matmul), so block shapes are
lane-aligned (BLOCK_G multiple of 8, BLOCK_N multiple of 128) and sized so
one (G,N) f32 tile (BLOCK_G*BLOCK_N*4 bytes) stays well under VMEM.
On this repo's CPU CI the kernel runs under interpret=True (Mosaic
custom-calls cannot execute on the CPU PJRT plugin).

VMEM budget at the default (BLOCK_G=64, BLOCK_N=1024):
  working tile 64*1024*4 = 256 KiB, operands 5*4 KiB + 256 B,
  outputs 3*256 B  ->  ~0.27 MiB << 16 MiB VMEM; FLOP/byte ≈ 64*6/4 ≈ 96,
  comfortably compute-bound on the VPU roofline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_G = 64
DEFAULT_BLOCK_N = 1024


def _cost_curve_kernel(lam_ref, m_ref, c_ref, s_ref, w_ref, t_ref,
                       cost_ref, vsize_ref, miss_ref):
    """One (BLOCK_G, BLOCK_N) tile: compute partial sums, accumulate."""
    n_idx = pl.program_id(1)

    lam = lam_ref[...]          # (BLOCK_N,)
    m = m_ref[...]
    c = c_ref[...]
    s = s_ref[...]
    w = w_ref[...]
    t = t_ref[...]              # (BLOCK_G,)

    e = jnp.exp(-lam[None, :] * t[:, None])          # (BLOCK_G, BLOCK_N)
    cost_tile = jnp.sum(w * (c + (lam * m - c) * e), axis=1)
    vsize_tile = jnp.sum(w * s * (1.0 - e), axis=1)
    miss_tile = jnp.sum(w * lam * e, axis=1)

    # First N-step initializes the accumulators; later steps add.
    @pl.when(n_idx == 0)
    def _init():
        cost_ref[...] = cost_tile
        vsize_ref[...] = vsize_tile
        miss_ref[...] = miss_tile

    @pl.when(n_idx != 0)
    def _acc():
        cost_ref[...] += cost_tile
        vsize_ref[...] += vsize_tile
        miss_ref[...] += miss_tile


@functools.partial(jax.jit, static_argnames=("block_g", "block_n", "interpret"))
def cost_curves(lam, miss_cost, storage_rate, size, weight, t_grid,
                block_g=DEFAULT_BLOCK_G, block_n=DEFAULT_BLOCK_N,
                interpret=True):
    """Tiled Pallas evaluation of the cost curves.

    Requires N % block_n == 0 and G % block_g == 0 (aot.py pads buckets
    with zero-weight entries, which contribute exactly nothing to any
    curve, so padding is semantically free).
    """
    n = lam.shape[0]
    g = t_grid.shape[0]
    bg = min(block_g, g)
    bn = min(block_n, n)
    assert n % bn == 0, f"N={n} not a multiple of block_n={bn}"
    assert g % bg == 0, f"G={g} not a multiple of block_g={bg}"
    grid = (g // bg, n // bn)

    out_shape = [jax.ShapeDtypeStruct((g,), jnp.float32)] * 3
    per_n = pl.BlockSpec((bn,), lambda i, j: (j,))
    per_g = pl.BlockSpec((bg,), lambda i, j: (i,))

    cost, vsize, miss = pl.pallas_call(
        _cost_curve_kernel,
        grid=grid,
        in_specs=[per_n, per_n, per_n, per_n, per_n, per_g],
        out_specs=[per_g, per_g, per_g],
        out_shape=out_shape,
        interpret=interpret,
    )(
        lam.astype(jnp.float32),
        miss_cost.astype(jnp.float32),
        storage_rate.astype(jnp.float32),
        size.astype(jnp.float32),
        weight.astype(jnp.float32),
        t_grid.astype(jnp.float32),
    )
    return cost, vsize, miss
