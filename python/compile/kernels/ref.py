"""Pure-jnp oracle for the cost-curve kernel.

Implements eq. (4) of the paper and its companions exactly, with no
tiling — the correctness reference the Pallas kernel is tested against:

    cost(T)     = sum_i w_i * (c_i + (lam_i * m_i - c_i) * exp(-lam_i T))
    vsize(T)    = sum_i w_i * s_i * (1 - exp(-lam_i T))
    missrate(T) = sum_i w_i * lam_i * exp(-lam_i T)

Shapes: per-content arrays are (N,), the T grid is (G,); outputs are (G,).
All float32 (the artifact interface), so the oracle and the kernel share
rounding behaviour.
"""

import jax.numpy as jnp


def cost_curves_ref(lam, miss_cost, storage_rate, size, weight, t_grid):
    """Evaluate the three curves. Returns (cost, vsize, missrate), each (G,).

    Broadcasting layout: (G, 1) x (1, N) -> (G, N) -> reduce over N.
    """
    lam = lam.astype(jnp.float32)
    m = miss_cost.astype(jnp.float32)
    c = storage_rate.astype(jnp.float32)
    s = size.astype(jnp.float32)
    w = weight.astype(jnp.float32)
    t = t_grid.astype(jnp.float32)

    e = jnp.exp(-lam[None, :] * t[:, None])  # (G, N)
    cost = jnp.sum(
        w[None, :] * (c[None, :] + (lam[None, :] * m[None, :] - c[None, :]) * e),
        axis=1,
    )
    vsize = jnp.sum(w[None, :] * s[None, :] * (1.0 - e), axis=1)
    missrate = jnp.sum(w[None, :] * lam[None, :] * e, axis=1)
    return cost, vsize, missrate


def optimal_t_ref(lam, miss_cost, storage_rate, size, weight, t_grid):
    """Argmin of the cost curve over the grid: (t_star, cost_star)."""
    cost, _, _ = cost_curves_ref(lam, miss_cost, storage_rate, size, weight, t_grid)
    i = jnp.argmin(cost)
    return t_grid[i], cost[i]
