"""L2 — the JAX model of the paper's analytic cost theory (§4.1).

Wraps the L1 Pallas kernel (`kernels.cost_curve`) into the jitted function
that is AOT-lowered to the PJRT artifact: given bucketed per-content
statistics, evaluate the cost / virtual-size / miss-rate curves over a
T grid (eq. 4 and companions), plus derived quantities used by tests and
analysis (optimal T, the analytic gradient dC/dT that the
stochastic-approximation controller follows in expectation).

Python (and this module) run only at build time; the Rust coordinator
executes the compiled HLO at epoch boundaries.
"""

import jax
import jax.numpy as jnp

from .kernels.cost_curve import cost_curves as _pallas_cost_curves


def cost_model(lam, miss_cost, storage_rate, size, weight, t_grid,
               block_g=None, block_n=None):
    """The artifact entry point: three (G,) curves via the Pallas kernel."""
    kwargs = {}
    if block_g is not None:
        kwargs["block_g"] = block_g
    if block_n is not None:
        kwargs["block_n"] = block_n
    return _pallas_cost_curves(
        lam, miss_cost, storage_rate, size, weight, t_grid, **kwargs
    )


def cost_gradient(lam, miss_cost, storage_rate, weight, t_grid):
    """Analytic dC/dT (eq. 4 differentiated):

        dC/dT = -sum_i w_i * lam_i * (lam_i m_i - c_i) * exp(-lam_i T)

    The SA update's expected correction is proportional to -dC/dT; tests
    verify the kernel's cost curve is consistent with this gradient.
    """
    lam = lam.astype(jnp.float32)
    m = miss_cost.astype(jnp.float32)
    c = storage_rate.astype(jnp.float32)
    w = weight.astype(jnp.float32)
    t = t_grid.astype(jnp.float32)
    e = jnp.exp(-lam[None, :] * t[:, None])
    return -jnp.sum(w[None, :] * lam[None, :] * (lam[None, :] * m[None, :] - c[None, :]) * e,
                    axis=1)


def lowered_cost_model(n, g, block_g=64, block_n=1024):
    """Lower `cost_model` for fixed shapes (N buckets, G grid points)."""
    def spec(shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    bg = min(block_g, g)
    bn = min(block_n, n)

    def fn(lam, m, c, s, w, t):
        return cost_model(lam, m, c, s, w, t, block_g=bg, block_n=bn)

    return jax.jit(fn).lower(
        spec((n,)), spec((n,)), spec((n,)), spec((n,)), spec((n,)), spec((g,))
    )
