"""AOT export: lower the L2 cost model (with its L1 Pallas kernel) to HLO
*text* and write the artifact manifest the Rust runtime loads.

HLO text — NOT `lowered.compile()` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla_extension 0.5.1 the `xla` crate links
against rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
Outputs:
    artifacts/cost_curve_n{N}_g{G}.hlo.txt  (one per shape variant)
    artifacts/manifest.txt                  (`name n g path dtype` lines)
"""

import argparse
import os

from jax._src.lib import xla_client as xc

from .model import lowered_cost_model

# Shape variants: (n_buckets, grid_points, block_g, block_n).
# The small variant keeps tests fast; the large one is the planner default.
VARIANTS = [
    (256, 64, 16, 256),
    (1024, 128, 32, 1024),
    (4096, 256, 64, 1024),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--out", default=None,
                    help="(compat) single-artifact output path; also "
                         "triggers the full multi-variant export next to it")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest_lines = ["# name n g path dtype"]
    for n, g, bg, bn in VARIANTS:
        lowered = lowered_cost_model(n, g, block_g=bg, block_n=bn)
        text = to_hlo_text(lowered)
        fname = f"cost_curve_n{n}_g{g}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"cost_curve {n} {g} {fname} f32")
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest}")

    # Compat marker for Makefile timestamp tracking.
    if args.out is not None:
        with open(args.out, "w") as f:
            f.write("see manifest.txt\n")


if __name__ == "__main__":
    main()
