"""pytest: Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

Sweeps shapes, block configurations, and value regimes (including the
paper's actual cost magnitudes: λ in [1e-6, 1e3] r/s, m ≈ 1.5e-7 $,
c ≈ 8.5e-15·s $/s) with hypothesis when available, falling back to a
seeded parameter sweep otherwise (the CI image may not ship hypothesis).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.cost_curve import cost_curves
from compile.kernels.ref import cost_curves_ref, optimal_t_ref

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def make_inputs(rng, n, g, lam_hi=10.0, size_hi=1e7):
    lam = rng.uniform(1e-6, lam_hi, size=n).astype(np.float32)
    m = np.full(n, 1.4676e-7, dtype=np.float32)
    s = rng.uniform(64.0, size_hi, size=n).astype(np.float32)
    c = (s * 8.5085e-15).astype(np.float32)
    w = rng.uniform(0.0, 100.0, size=n).astype(np.float32)
    t = np.linspace(0.0, 7200.0, g).astype(np.float32)
    return lam, m, c, s, w, t


def assert_curves_close(n, g, block_g, block_n, seed=0, lam_hi=10.0):
    rng = np.random.default_rng(seed)
    lam, m, c, s, w, t = make_inputs(rng, n, g, lam_hi=lam_hi)
    got = cost_curves(jnp.array(lam), jnp.array(m), jnp.array(c),
                      jnp.array(s), jnp.array(w), jnp.array(t),
                      block_g=block_g, block_n=block_n)
    want = cost_curves_ref(jnp.array(lam), jnp.array(m), jnp.array(c),
                           jnp.array(s), jnp.array(w), jnp.array(t))
    names = ["cost", "vsize", "missrate"]
    for name, a, b in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-12,
            err_msg=f"{name} mismatch at n={n} g={g} bg={block_g} bn={block_n}",
        )


@pytest.mark.parametrize("n,g,bg,bn", [
    (128, 16, 16, 128),
    (256, 64, 16, 256),
    (256, 64, 64, 64),     # multiple N-steps per G tile
    (1024, 128, 32, 1024),
    (1024, 32, 32, 128),   # 8 accumulation steps
    (64, 8, 8, 64),
])
def test_kernel_matches_ref_shapes(n, g, bg, bn):
    assert_curves_close(n, g, bg, bn, seed=n + g)


@pytest.mark.parametrize("seed", range(5))
def test_kernel_matches_ref_random_values(seed):
    assert_curves_close(256, 64, 16, 256, seed=seed, lam_hi=1000.0)


def test_zero_weight_buckets_are_free():
    """Padding buckets (weight 0) must not change any curve."""
    rng = np.random.default_rng(1)
    lam, m, c, s, w, t = make_inputs(rng, 256, 32)
    w2 = w.copy()
    w2[128:] = 0.0
    got = cost_curves(jnp.array(lam), jnp.array(m), jnp.array(c),
                      jnp.array(s), jnp.array(w2), jnp.array(t),
                      block_g=16, block_n=128)
    want = cost_curves_ref(jnp.array(lam[:128]), jnp.array(m[:128]),
                           jnp.array(c[:128]), jnp.array(s[:128]),
                           jnp.array(w2[:128]), jnp.array(t))
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4)


def test_limits_match_eq4():
    """T=0: all misses (cost = Σ w λ m); T→∞: all storage (cost = Σ w c)."""
    rng = np.random.default_rng(2)
    lam, m, c, s, w, _ = make_inputs(rng, 128, 16)
    t = np.array([0.0] * 8 + [1e9] * 8, dtype=np.float32)
    cost, vsize, missrate = cost_curves(
        jnp.array(lam), jnp.array(m), jnp.array(c), jnp.array(s),
        jnp.array(w), jnp.array(t), block_g=8, block_n=128)
    all_miss = float(np.sum(w * lam * m))
    all_store = float(np.sum(w * c))
    np.testing.assert_allclose(np.asarray(cost)[0], all_miss, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cost)[-1], all_store, rtol=1e-3)
    assert np.asarray(vsize)[0] == 0.0
    np.testing.assert_allclose(np.asarray(vsize)[-1], float(np.sum(w * s)), rtol=1e-4)
    assert np.asarray(missrate)[-1] < np.asarray(missrate)[0]


def test_missrate_monotone_decreasing():
    rng = np.random.default_rng(3)
    lam, m, c, s, w, t = make_inputs(rng, 256, 64)
    _, _, missrate = cost_curves(jnp.array(lam), jnp.array(m), jnp.array(c),
                                 jnp.array(s), jnp.array(w), jnp.array(t),
                                 block_g=16, block_n=256)
    mr = np.asarray(missrate)
    assert np.all(np.diff(mr) <= 1e-6 * (1 + np.abs(mr[:-1])))


def test_optimal_t_is_interior_when_mixed_population():
    """Hot small objects + cold giants ⇒ optimum strictly inside (0, Tmax)."""
    n, g = 128, 64
    lam = np.concatenate([np.full(n // 2, 1.0), np.full(n // 2, 1e-5)]).astype(np.float32)
    s = np.concatenate([np.full(n // 2, 1e4), np.full(n // 2, 2e7)]).astype(np.float32)
    m = np.full(n, 1.4676e-7, dtype=np.float32)
    c = (s * 8.5085e-15).astype(np.float32)
    w = np.concatenate([np.full(n // 2, 1.0), np.full(n // 2, 1000.0)]).astype(np.float32)
    # geometric grid: the optimum sits at small T (the hot objects are
    # fully retained within seconds; the giants' storage grows linearly)
    t = np.concatenate([[0.0], np.geomspace(1.0, 24 * 3600.0, g - 1)]).astype(np.float32)
    t_star, _ = optimal_t_ref(jnp.array(lam), jnp.array(m), jnp.array(c),
                              jnp.array(s), jnp.array(w), jnp.array(t))
    assert 0.0 < float(t_star) < 24 * 3600.0
    cost, _, _ = cost_curves(jnp.array(lam), jnp.array(m), jnp.array(c),
                             jnp.array(s), jnp.array(w), jnp.array(t),
                             block_g=16, block_n=128)
    i = int(np.argmin(np.asarray(cost)))
    np.testing.assert_allclose(float(t[i]), float(t_star), rtol=1e-6)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        n_blocks=st.integers(min_value=1, max_value=4),
        g_blocks=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31),
        lam_exp=st.floats(min_value=-5.0, max_value=3.0),
    )
    def test_hypothesis_shape_value_sweep(n_blocks, g_blocks, seed, lam_exp):
        n = 64 * n_blocks
        g = 8 * g_blocks
        assert_curves_close(n, g, 8, 64, seed=seed % 10_000,
                            lam_hi=10.0 ** lam_exp + 1e-6)
else:

    @pytest.mark.parametrize("case", range(30))
    def test_fallback_shape_value_sweep(case):
        rng = np.random.default_rng(case)
        n = 64 * int(rng.integers(1, 5))
        g = 8 * int(rng.integers(1, 5))
        lam_hi = 10.0 ** rng.uniform(-5, 3) + 1e-6
        assert_curves_close(n, g, 8, 64, seed=case, lam_hi=lam_hi)
