"""pytest: L2 model — gradient consistency, lowering shapes, and the
HLO-text export path the Rust runtime consumes."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import cost_gradient, cost_model, lowered_cost_model
from compile.aot import to_hlo_text
from compile.kernels.ref import cost_curves_ref


def inputs(n, g, seed=0):
    rng = np.random.default_rng(seed)
    lam = rng.uniform(1e-4, 5.0, n).astype(np.float32)
    m = np.full(n, 1.4676e-7, dtype=np.float32)
    s = rng.uniform(100, 1e6, n).astype(np.float32)
    c = (s * 8.5085e-15).astype(np.float32)
    w = rng.uniform(0.5, 50.0, n).astype(np.float32)
    t = np.linspace(0.0, 3600.0, g).astype(np.float32)
    return tuple(jnp.array(x) for x in (lam, m, c, s, w, t))


def test_gradient_matches_finite_differences():
    lam, m, c, s, w, t = inputs(128, 32, seed=4)
    # Keep rates moderate and the grid off the origin so the O(eps^2)
    # curvature term of central differences stays below the tolerance
    # (f32 cost values cap how small eps can go).
    lam = lam / 10.0
    t = t + 5.0
    grad = np.asarray(cost_gradient(lam, m, c, w, t))
    # Central differences on the reference cost curve.
    eps = 0.5
    cost_p, _, _ = cost_curves_ref(lam, m, c, s, w, t + eps)
    cost_m, _, _ = cost_curves_ref(lam, m, c, s, w, t - eps)
    fd = (np.asarray(cost_p) - np.asarray(cost_m)) / (2 * eps)
    scale = np.abs(grad).max() + 1e-30
    np.testing.assert_allclose(grad / scale, fd / scale, atol=1e-2)


def test_gradient_sign_structure():
    """At T=0 with all-hot objects the gradient must be negative (growing T
    reduces cost); with all-cold giant objects it must be positive."""
    g = 4
    t = jnp.array(np.zeros(g, dtype=np.float32) + 1.0)
    hot = cost_gradient(
        jnp.full((64,), 2.0), jnp.full((64,), 1e-6),
        jnp.full((64,), 1e-12), jnp.full((64,), 1.0), t)
    assert float(np.asarray(hot)[0]) < 0.0
    cold = cost_gradient(
        jnp.full((64,), 1e-6), jnp.full((64,), 1e-9),
        jnp.full((64,), 1e-6), jnp.full((64,), 1.0), t)
    assert float(np.asarray(cold)[0]) > 0.0


def test_model_shapes():
    lam, m, c, s, w, t = inputs(256, 64, seed=5)
    cost, vsize, miss = cost_model(lam, m, c, s, w, t, block_g=16, block_n=256)
    assert cost.shape == (64,)
    assert vsize.shape == (64,)
    assert miss.shape == (64,)
    assert cost.dtype == jnp.float32


@pytest.mark.parametrize("n,g", [(256, 64), (64, 8)])
def test_lowering_produces_hlo_text(n, g):
    lowered = lowered_cost_model(n, g, block_g=min(8, g), block_n=min(64, n))
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # 6 parameters and a 3-tuple result with the right shapes.
    assert f"f32[{n}]" in text
    assert f"f32[{g}]" in text
    assert "ROOT" in text


def test_lowered_executes_and_matches_ref():
    n, g = 64, 8
    lam, m, c, s, w, t = inputs(n, g, seed=6)
    lowered = lowered_cost_model(n, g, block_g=8, block_n=64)
    compiled = lowered.compile()
    got = compiled(lam, m, c, s, w, t)
    want = cost_curves_ref(lam, m, c, s, w, t)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4)
