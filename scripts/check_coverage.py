#!/usr/bin/env python3
"""Advisory module-coverage check for the CI coverage job.

Parses an LCOV info file (as written by `cargo llvm-cov --lcov`),
aggregates line coverage per watched module prefix, and emits a GitHub
Actions `::warning` for any module below the threshold. The check is
advisory by design: it always exits 0, so a coverage dip shows up in the
run annotations without turning the build red.

Usage:
    check_coverage.py lcov.info [--threshold 70] \
        [--module engine=rust/src/engine ...]
"""

import argparse
import sys

DEFAULT_MODULES = [
    "engine=rust/src/engine",
    "tenant=rust/src/tenant",
    "admission=rust/src/admission",
]


def parse_lcov(path):
    """Return {source_file: (lines_found, lines_hit)} from an LCOV file.

    Counts DA: records directly (always present), so files missing the
    optional LF:/LH: summary lines still aggregate correctly.
    """
    per_file = {}
    current, found, hit = None, 0, 0
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line.startswith("SF:"):
                current, found, hit = line[3:], 0, 0
            elif line.startswith("DA:") and current is not None:
                found += 1
                if int(line[3:].split(",")[1]) > 0:
                    hit += 1
            elif line == "end_of_record" and current is not None:
                prev = per_file.get(current, (0, 0))
                per_file[current] = (prev[0] + found, prev[1] + hit)
                current = None
    return per_file


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("lcov", help="LCOV info file from cargo llvm-cov")
    ap.add_argument("--threshold", type=float, default=70.0)
    ap.add_argument(
        "--module",
        action="append",
        default=None,
        metavar="NAME=PATH_PREFIX",
        help="watched module (repeatable); default: engine, tenant, admission",
    )
    args = ap.parse_args()

    modules = [m.split("=", 1) for m in (args.module or DEFAULT_MODULES)]
    per_file = parse_lcov(args.lcov)
    if not per_file:
        print(f"::warning::coverage: {args.lcov} contains no records")
        return 0

    warned = False
    for name, prefix in modules:
        found = hit = 0
        for src, (f, h) in per_file.items():
            # llvm-cov emits absolute paths; match on the repo-relative tail.
            if prefix in src.replace("\\", "/"):
                found += f
                hit += h
        if found == 0:
            print(f"::warning::coverage: no lines found under {prefix}")
            warned = True
            continue
        pct = 100.0 * hit / found
        marker = "" if pct >= args.threshold else "  <-- below threshold"
        print(f"coverage: {name:<10} {pct:6.2f}%  ({hit}/{found} lines){marker}")
        if pct < args.threshold:
            print(
                f"::warning::coverage: {name} line coverage {pct:.2f}% "
                f"is below the advisory {args.threshold:.0f}% bar"
            )
            warned = True
    if not warned:
        print(f"coverage: all watched modules at or above {args.threshold:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
