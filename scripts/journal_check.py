#!/usr/bin/env python3
"""Invariant pass over an epoch decision journal (JSONL), a server
billing checkpoint (`srv::checkpoint` length-prefixed JSONL), or a
sharded `METRICS` scrape (Prometheus text exposition).

Usage: journal_check.py <journal.jsonl|server.ckpt|metrics.prom> [more ...]

The file kind is auto-detected per file: a line shaped
`<byte-length> {json}` is a checkpoint record (the format `elastictl
serve --checkpoint` appends, fsync'd per closed epoch); a line starting
with `#` or a bare metric name is Prometheus text (what the sharded
front answers to `METRICS`); anything else is one `EpochDecisionRecord`
as written by `engine::run` when `[telemetry] journal_path` is set (see
docs/OBSERVABILITY.md for the schema). The nightly soak runs this over
the fig14-obs journal, over the kill/resume serve soak's checkpoint, and
over the METRICS scrape taken from the sharded soak leg; any violation
exits 1 so the soaks surface engine bugs, not just slow drifts.

Checked per decision record:
  * arbiter bound:   Σ granted_bytes over tenants ≤ capacity_bytes
  * grant split:     reserved_bytes + pooled_bytes == granted_bytes
                     (whenever the grant covers the reservation)
  * shed bound:      shed_bytes ≤ resident_before_bytes
  * billing fold:    Σ per-tenant bill dollars ≈ the record's cluster
                     dollars (attribution must neither drop nor invent
                     money; 0.1% relative tolerance for rounding)

Checked across the journal (only when it starts at epoch 0, i.e. the
bounded ring never evicted):
  * reconciliation:  for every tenant with a `reconciled_dollars` row,
                     the reconciled total equals the sum of its per-epoch
                     bills (delta ≈ 0) — retirement must bill exactly
                     what the epochs billed.

Checked on a sharded METRICS scrape:
  * grammar:         every non-comment line is `name[{labels}] value`
  * shard labels:    `shard="i"` series exist, each (metric, shard) pair
                     appears at most once, every unlabeled metric's shard
                     set is contiguous from 0, and all metrics agree on
                     the shard width
  * merge closure:   for every shard-labeled series the unlabeled
                     cluster-level sample equals the sum of its per-shard
                     samples (the merged exposition must neither drop nor
                     invent traffic — exact for counters, 1e-6 relative
                     for gauges)
  * request path:    per-shard `elastictl_requests_total` series present

Checked on a checkpoint file:
  * framing:         each length prefix matches its record's byte length
                     (a torn final record — a mid-write kill — is
                     tolerated and reported, mirroring the Rust reader;
                     torn or malformed *interior* records are errors)
  * continuity:      epoch numbers are contiguous ascending
  * attribution:     Σ per-tenant bill rows ≈ the epoch's storage / miss
                     dollars
  * cumulative fold: the running sums of the per-epoch dollars ≈ the
                     record's cum_* fields (files starting at epoch 1)
  * ledger closure:  Σ per-tenant ledgers ≈ the cumulative totals, and
                     every reconciliation's total equals its parts
"""

import json
import re
import sys


def approx(a: float, b: float, rel: float = 1e-3, abs_tol: float = 1e-9) -> bool:
    return abs(a - b) <= max(abs_tol, rel * max(abs(a), abs(b)))


def looks_like_checkpoint(line: str) -> bool:
    """`<decimal length> {json}` — the srv::checkpoint framing."""
    head, _, rest = line.partition(" ")
    return head.isdigit() and rest.startswith("{")


SAMPLE_RE = re.compile(r"^([A-Za-z_:][A-Za-z0-9_:]*)(\{[^}]*\})? (\S+)$")
LABEL_RE = re.compile(r'(\w+)="([^"]*)"')


def looks_like_metrics(line: str) -> bool:
    """A Prometheus comment or a `name[{labels}] value` sample."""
    return line.startswith("#") or SAMPLE_RE.match(line) is not None


def check_metrics_file(path: str, lines: list[tuple[int, str]]) -> int:
    violations = 0

    def bad(msg: str) -> None:
        nonlocal violations
        violations += 1
        print(f"::error title=metrics invariant::{path}: {msg}")

    # (name, non-shard labels) -> the unlabeled cluster sample (if any)
    # plus every `shard="i"` sample, so the merge closure can be checked
    # per series family.
    series: dict[tuple, dict] = {}
    saw_eof = False
    for lineno, line in lines:
        if line == "# EOF":
            saw_eof = True
            continue
        if line.startswith("#"):
            continue
        m = SAMPLE_RE.match(line)
        if m is None:
            bad(f"line {lineno}: not a metric sample: {line!r}")
            continue
        name, labelblock, value = m.groups()
        try:
            v = float(value)
        except ValueError:
            bad(f"line {lineno}: unparseable value {value!r}")
            continue
        labels = dict(LABEL_RE.findall(labelblock or ""))
        shard = labels.pop("shard", None)
        key = (name, tuple(sorted(labels.items())))
        entry = series.setdefault(key, {"plain": None, "shards": {}})
        if shard is None:
            if entry["plain"] is not None:
                bad(f"line {lineno}: duplicate series {name}{labelblock or ''}")
            entry["plain"] = v
        elif not shard.isdigit():
            bad(f"line {lineno}: non-numeric shard label {shard!r}")
        elif int(shard) in entry["shards"]:
            bad(f"line {lineno}: duplicate shard {shard} sample for {name}")
        else:
            entry["shards"][int(shard)] = v

    sharded = {key: e for key, e in series.items() if e["shards"]}
    if not sharded:
        bad('no shard="i" series (not a sharded METRICS scrape?)')
        return violations
    width = 1 + max(max(e["shards"]) for e in sharded.values())
    for (name, labels), e in sorted(sharded.items()):
        what = name + "".join(f"{{{k}={v}}}" for k, v in labels)
        idx = sorted(e["shards"])
        if labels:
            # Tenant-labeled series appear only on shards that saw the
            # tenant — any subset of the width is fine.
            if idx[-1] >= width:
                bad(f"{what}: shard {idx[-1]} outside the {width}-shard width")
        elif idx != list(range(width)):
            bad(f"{what}: shard labels {idx}, want contiguous 0..{width - 1}")
        if e["plain"] is None:
            bad(f"{what}: per-shard series but no cluster-level sum sample")
        elif not approx(sum(e["shards"].values()), e["plain"], rel=1e-6, abs_tol=1e-6):
            bad(
                f"{what}: Σ shard samples {sum(e['shards'].values()):.9f} != "
                f"cluster sum {e['plain']:.9f}"
            )
    if all(name != "elastictl_requests_total" for name, _ in sharded):
        bad("no per-shard elastictl_requests_total series")
    if not saw_eof:
        print(f"{path}: no # EOF terminator (truncated scrape?)")

    if violations == 0:
        print(
            f"{path}: {len(sharded)} shard-labeled series over {width} shard(s), "
            "all invariants hold"
        )
    return violations


def check_checkpoint_file(path: str, lines: list[tuple[int, str]]) -> int:
    violations = 0

    def bad(msg: str) -> None:
        nonlocal violations
        violations += 1
        print(f"::error title=checkpoint invariant::{path}: {msg}")

    records = []
    for i, (lineno, line) in enumerate(lines):
        last = i + 1 == len(lines)
        head, _, body = line.partition(" ")
        torn = None
        if not looks_like_checkpoint(line):
            torn = "not a length-prefixed record"
        elif int(head) != len(body.encode()):
            torn = f"length prefix {head} != {len(body.encode())} payload bytes"
        else:
            try:
                records.append(json.loads(body))
            except json.JSONDecodeError as e:
                torn = f"not valid JSON ({e})"
        if torn is not None:
            # A torn *final* record is a mid-write kill: dropped without
            # error, exactly as the Rust reader replays the file.
            if last:
                print(f"{path}: line {lineno}: torn tail dropped ({torn})")
            else:
                bad(f"line {lineno}: {torn}")
    if not records:
        bad("no intact records (checkpoint empty or unreadable)")
        return violations

    first_epoch = records[0].get("epoch")
    if not isinstance(first_epoch, int):
        bad(f"first record carries no epoch number: {records[0]}")
        return violations
    cum_storage = 0.0
    cum_miss = 0.0
    for i, rec in enumerate(records):
        epoch = rec.get("epoch", "?")
        if rec.get("v") != 1:
            bad(f"epoch {epoch}: unknown checkpoint version {rec.get('v')}")
        if epoch != first_epoch + i:
            bad(f"record {i}: epoch {epoch}, want contiguous {first_epoch + i}")
        bills = rec.get("bills", [])
        if bills:
            for field, total in [("storage", rec["storage_dollars"]), ("miss", rec["miss_dollars"])]:
                s = sum(b[field] for b in bills)
                if not approx(s, total):
                    bad(
                        f"epoch {epoch}: Σ bill {field} {s:.9f} != epoch "
                        f"{field} dollars {total:.9f}"
                    )
        for r in rec.get("reconciliations", []):
            if not approx(r["total_dollars"], r["miss_dollars"] + r["storage_dollars"]):
                bad(
                    f"epoch {epoch} tenant {r['tenant']}: reconciliation total "
                    f"{r['total_dollars']:.9f} != miss + storage parts"
                )
        cum_storage += rec["storage_dollars"]
        cum_miss += rec["miss_dollars"]
        if first_epoch == 1:
            if not approx(cum_storage, rec["cum_storage_dollars"]):
                bad(
                    f"epoch {epoch}: cum_storage_dollars {rec['cum_storage_dollars']:.9f} "
                    f"!= running fold {cum_storage:.9f}"
                )
            if not approx(cum_miss, rec["cum_miss_dollars"]):
                bad(
                    f"epoch {epoch}: cum_miss_dollars {rec['cum_miss_dollars']:.9f} "
                    f"!= running fold {cum_miss:.9f}"
                )

    last = records[-1]
    ledgers = last.get("ledgers", [])
    if first_epoch == 1 and ledgers:
        for field, cum in [("storage_dollars", "cum_storage_dollars"),
                           ("miss_dollars", "cum_miss_dollars")]:
            s = sum(led[field] for led in ledgers)
            if not approx(s, last[cum]):
                bad(f"Σ ledger {field} {s:.9f} != {cum} {last[cum]:.9f}")
    elif first_epoch != 1:
        print(f"{path}: starts at epoch {first_epoch} — skipping cumulative cross-checks")

    if violations == 0:
        print(f"{path}: {len(records)} checkpoint records, all invariants hold")
    return violations


def check_file(path: str) -> int:
    violations = 0

    def bad(msg: str) -> None:
        nonlocal violations
        violations += 1
        print(f"::error title=journal invariant::{path}: {msg}")

    lines = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if line:
                lines.append((lineno, line))
    if lines and looks_like_checkpoint(lines[0][1]):
        return check_checkpoint_file(path, lines)
    if lines and looks_like_metrics(lines[0][1]):
        return check_metrics_file(path, lines)

    records = []
    for lineno, line in lines:
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as e:
            bad(f"line {lineno}: not valid JSON ({e})")
    if not records:
        bad("no records (journal empty or unreadable)")
        return violations

    bills: dict[int, float] = {}
    reconciled: dict[int, float] = {}
    for rec in records:
        epoch = rec.get("epoch", "?")
        tenants = rec.get("tenants", [])
        granted = sum(d["granted_bytes"] for d in tenants)
        if granted > rec["capacity_bytes"]:
            bad(
                f"epoch {epoch}: Σ granted {granted} exceeds capacity "
                f"{rec['capacity_bytes']}"
            )
        bill_total = 0.0
        for d in tenants:
            t = d["tenant"]
            if d["granted_bytes"] >= d["reserved_bytes"]:
                if d["reserved_bytes"] + d["pooled_bytes"] != d["granted_bytes"]:
                    bad(
                        f"epoch {epoch} tenant {t}: reserved {d['reserved_bytes']} "
                        f"+ pooled {d['pooled_bytes']} != granted {d['granted_bytes']}"
                    )
            if d["shed_bytes"] > d["resident_before_bytes"]:
                bad(
                    f"epoch {epoch} tenant {t}: shed {d['shed_bytes']} exceeds "
                    f"resident {d['resident_before_bytes']}"
                )
            bill = d["bill_storage_dollars"] + d["bill_miss_dollars"]
            bill_total += bill
            bills[t] = bills.get(t, 0.0) + bill
            if d.get("reconciled_dollars") is not None:
                reconciled[t] = reconciled.get(t, 0.0) + d["reconciled_dollars"]
        rec_total = rec["storage_dollars"] + rec["miss_dollars"]
        if tenants and not approx(bill_total, rec_total):
            bad(
                f"epoch {epoch}: per-tenant bills sum to {bill_total:.9f} but the "
                f"record billed {rec_total:.9f}"
            )

    if records[0].get("epoch") == 0:
        for t, total in sorted(reconciled.items()):
            if not approx(total, bills.get(t, 0.0)):
                bad(
                    f"tenant {t}: reconciled {total:.9f} != Σ epoch bills "
                    f"{bills.get(t, 0.0):.9f}"
                )
    elif reconciled:
        print(
            f"{path}: journal ring evicted early epochs — skipping the "
            "reconciliation cross-check"
        )

    if violations == 0:
        print(f"{path}: {len(records)} records, all invariants hold")
    return violations


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    total = sum(check_file(p) for p in sys.argv[1:])
    if total:
        print(f"journal check: {total} violation(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
