#!/usr/bin/env python3
"""Invariant pass over an epoch decision journal (JSONL).

Usage: journal_check.py <journal.jsonl> [more.jsonl ...]

Each line is one `EpochDecisionRecord` as written by `engine::run` when
`[telemetry] journal_path` is set (see docs/OBSERVABILITY.md for the
schema). The nightly soak runs this over the fig14-obs journal; any
violation exits 1 so the soak surfaces engine bugs, not just slow drifts.

Checked per record:
  * arbiter bound:   Σ granted_bytes over tenants ≤ capacity_bytes
  * grant split:     reserved_bytes + pooled_bytes == granted_bytes
                     (whenever the grant covers the reservation)
  * shed bound:      shed_bytes ≤ resident_before_bytes
  * billing fold:    Σ per-tenant bill dollars ≈ the record's cluster
                     dollars (attribution must neither drop nor invent
                     money; 0.1% relative tolerance for rounding)

Checked across the journal (only when it starts at epoch 0, i.e. the
bounded ring never evicted):
  * reconciliation:  for every tenant with a `reconciled_dollars` row,
                     the reconciled total equals the sum of its per-epoch
                     bills (delta ≈ 0) — retirement must bill exactly
                     what the epochs billed.
"""

import json
import sys


def approx(a: float, b: float, rel: float = 1e-3, abs_tol: float = 1e-9) -> bool:
    return abs(a - b) <= max(abs_tol, rel * max(abs(a), abs(b)))


def check_file(path: str) -> int:
    violations = 0

    def bad(msg: str) -> None:
        nonlocal violations
        violations += 1
        print(f"::error title=journal invariant::{path}: {msg}")

    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                bad(f"line {lineno}: not valid JSON ({e})")
    if not records:
        bad("no records (journal empty or unreadable)")
        return violations

    bills: dict[int, float] = {}
    reconciled: dict[int, float] = {}
    for rec in records:
        epoch = rec.get("epoch", "?")
        tenants = rec.get("tenants", [])
        granted = sum(d["granted_bytes"] for d in tenants)
        if granted > rec["capacity_bytes"]:
            bad(
                f"epoch {epoch}: Σ granted {granted} exceeds capacity "
                f"{rec['capacity_bytes']}"
            )
        bill_total = 0.0
        for d in tenants:
            t = d["tenant"]
            if d["granted_bytes"] >= d["reserved_bytes"]:
                if d["reserved_bytes"] + d["pooled_bytes"] != d["granted_bytes"]:
                    bad(
                        f"epoch {epoch} tenant {t}: reserved {d['reserved_bytes']} "
                        f"+ pooled {d['pooled_bytes']} != granted {d['granted_bytes']}"
                    )
            if d["shed_bytes"] > d["resident_before_bytes"]:
                bad(
                    f"epoch {epoch} tenant {t}: shed {d['shed_bytes']} exceeds "
                    f"resident {d['resident_before_bytes']}"
                )
            bill = d["bill_storage_dollars"] + d["bill_miss_dollars"]
            bill_total += bill
            bills[t] = bills.get(t, 0.0) + bill
            if d.get("reconciled_dollars") is not None:
                reconciled[t] = reconciled.get(t, 0.0) + d["reconciled_dollars"]
        rec_total = rec["storage_dollars"] + rec["miss_dollars"]
        if tenants and not approx(bill_total, rec_total):
            bad(
                f"epoch {epoch}: per-tenant bills sum to {bill_total:.9f} but the "
                f"record billed {rec_total:.9f}"
            )

    if records[0].get("epoch") == 0:
        for t, total in sorted(reconciled.items()):
            if not approx(total, bills.get(t, 0.0)):
                bad(
                    f"tenant {t}: reconciled {total:.9f} != Σ epoch bills "
                    f"{bills.get(t, 0.0):.9f}"
                )
    elif reconciled:
        print(
            f"{path}: journal ring evicted early epochs — skipping the "
            "reconciliation cross-check"
        )

    if violations == 0:
        print(f"{path}: {len(records)} records, all invariants hold")
    return violations


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    total = sum(check_file(p) for p in sys.argv[1:])
    if total:
        print(f"journal check: {total} violation(s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
