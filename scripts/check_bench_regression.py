#!/usr/bin/env python3
"""Compare a quick-bench JSON summary against the committed baseline.

Usage: check_bench_regression.py <baseline.json> <current.json>
           [--append-history BENCH_HISTORY.jsonl]

The baseline (rust/benches/baseline.json) maps bench names to the
throughput floor they are expected to sustain (elements/second, as
emitted by the bench harness when ELASTICTL_BENCH_JSON is set). A run
whose throughput drops more than `tolerance` below its baseline is
reported as a regression via a GitHub Actions ::warning:: annotation.

The throughput gate is advisory (quick-mode numbers on shared CI
runners are noisy, so it warns instead of failing). To ratchet the
baseline, copy numbers from the BENCH_<sha>.json artifact of a healthy
run into rust/benches/baseline.json — keep them conservative (below
typical runner throughput) so only real regressions trip. Rows present
in the current run but absent from the baseline draw a ::warning:: so
new benches get floors instead of silently escaping the gate.

The baseline's "scaling" section is the one hard gate: each rule
requires `row` to sustain at least `min_ratio` times the throughput of
`vs` (e.g. the 8-shard engine vs the single-shard engine). The ratio is
enforced with exit code 1 only when the runner has at least `min_cores`
CPUs (os.cpu_count()); below that a shard-starved runner cannot
demonstrate the speedup, so the rule downgrades to a ::warning::.

`--append-history` appends one JSON line per run (UTC timestamp, commit
sha from $GITHUB_SHA, suite name, per-bench throughput and p50/p999
latencies) to the named JSONL file, so regressions can be judged against
a trend rather than a single baseline snapshot. The CI quick-bench job
appends to the repo-root BENCH_HISTORY.jsonl and uploads it as an
artifact each run.
"""

import json
import os
import sys
import time


def append_history(path: str, current: dict) -> None:
    entry = {
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sha": os.environ.get("GITHUB_SHA", "local"),
        "suite": current.get("suite", "?"),
        "results": {
            r["name"]: {
                "throughput_per_sec": r.get("throughput_per_sec", 0.0),
                "p50_ns": r.get("p50_ns", 0.0),
                "p999_ns": r.get("p999_ns", 0.0),
            }
            for r in current.get("results", [])
        },
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"bench history: appended {entry['suite']} @ {entry['sha'][:12]} to {path}")


def main() -> int:
    args = sys.argv[1:]
    history = None
    if "--append-history" in args:
        i = args.index("--append-history")
        try:
            history = args[i + 1]
        except IndexError:
            print(__doc__)
            return 2
        del args[i : i + 2]
    if len(args) != 2:
        print(__doc__)
        return 2
    with open(args[0]) as f:
        baseline = json.load(f)
    with open(args[1]) as f:
        current = json.load(f)

    tolerance = float(baseline.get("tolerance", 0.10))
    floors = baseline.get("throughput_per_sec", {})
    results = {r["name"]: r for r in current.get("results", [])}

    regressions = []
    print(f"{'bench':<44} {'baseline/s':>14} {'current/s':>14}  verdict")
    for name, floor in sorted(floors.items()):
        row = results.get(name)
        if row is None:
            print(f"{name:<44} {floor:>14.0f} {'missing':>14}  ::warning — bench not run")
            regressions.append((name, floor, None))
            continue
        tput = float(row.get("throughput_per_sec", 0.0))
        limit = floor * (1.0 - tolerance)
        verdict = "ok" if tput >= limit else "REGRESSION"
        print(f"{name:<44} {floor:>14.0f} {tput:>14.0f}  {verdict}")
        if tput < limit:
            regressions.append((name, floor, tput))
    for name in sorted(set(results) - set(floors)):
        tput = float(results[name].get("throughput_per_sec", 0.0))
        print(f"{name:<44} {'(no baseline)':>14} {tput:>14.0f}  new — consider adding")
        print(
            f"::warning title=bench baseline missing::{name}: {tput:.0f}/s has no "
            f"baseline floor — add one to rust/benches/baseline.json"
        )

    if regressions:
        for name, floor, tput in regressions:
            got = "not run" if tput is None else f"{tput:.0f}/s"
            print(
                f"::warning title=bench regression::{name}: {got} vs baseline "
                f"{floor:.0f}/s (>{tolerance:.0%} drop)"
            )
    else:
        print(f"bench gate: all within {tolerance:.0%} of baseline")

    failures = check_scaling(baseline, results)

    if history is not None:
        append_history(history, current)
    return 1 if failures else 0


def check_scaling(baseline: dict, results: dict) -> list:
    """Enforce the baseline's scaling rules; returns the failed rows."""
    failures = []
    cores = os.cpu_count() or 0
    for rule in baseline.get("scaling", []):
        row, vs = rule["row"], rule["vs"]
        min_ratio = float(rule.get("min_ratio", 1.0))
        min_cores = int(rule.get("min_cores", 0))
        a, b = results.get(row), results.get(vs)
        if a is None or b is None:
            missing = row if a is None else vs
            print(
                f"::warning title=scaling gate skipped::{missing} not in the bench "
                f"output — cannot judge {row} vs {vs}"
            )
            continue
        num = float(a.get("throughput_per_sec", 0.0))
        den = float(b.get("throughput_per_sec", 0.0))
        ratio = num / den if den > 0 else 0.0
        enforced = cores >= min_cores
        mode = "enforced" if enforced else f"advisory — {cores} cores < {min_cores}"
        verdict = "ok" if ratio >= min_ratio else "BELOW TARGET"
        print(
            f"scaling {row} vs {vs}: {ratio:.2f}x "
            f"(min {min_ratio:.2f}x, {mode})  {verdict}"
        )
        if ratio >= min_ratio:
            continue
        if enforced:
            print(
                f"::error title=scaling regression::{row}: {ratio:.2f}x vs {vs} "
                f"(minimum {min_ratio:.2f}x on runners with >= {min_cores} cores)"
            )
            failures.append(row)
        else:
            print(
                f"::warning title=scaling below target::{row}: {ratio:.2f}x vs {vs} "
                f"(minimum {min_ratio:.2f}x; advisory on this {cores}-core runner)"
            )
    return failures


if __name__ == "__main__":
    sys.exit(main())
