#!/usr/bin/env python3
"""Compare a quick-bench JSON summary against the committed baseline.

Usage: check_bench_regression.py <baseline.json> <current.json>

The baseline (rust/benches/baseline.json) maps bench names to the
throughput floor they are expected to sustain (elements/second, as
emitted by the bench harness when ELASTICTL_BENCH_JSON is set). A run
whose throughput drops more than `tolerance` below its baseline is
reported as a regression via a GitHub Actions ::warning:: annotation.

The gate is advisory (exit code 0 either way): quick-mode numbers on
shared CI runners are noisy, so the job warns instead of failing. To
ratchet the baseline, copy numbers from the BENCH_<sha>.json artifact of
a healthy run into rust/benches/baseline.json — keep them conservative
(below typical runner throughput) so only real regressions trip.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        baseline = json.load(f)
    with open(sys.argv[2]) as f:
        current = json.load(f)

    tolerance = float(baseline.get("tolerance", 0.10))
    floors = baseline.get("throughput_per_sec", {})
    results = {r["name"]: r for r in current.get("results", [])}

    regressions = []
    print(f"{'bench':<44} {'baseline/s':>14} {'current/s':>14}  verdict")
    for name, floor in sorted(floors.items()):
        row = results.get(name)
        if row is None:
            print(f"{name:<44} {floor:>14.0f} {'missing':>14}  ::warning — bench not run")
            regressions.append((name, floor, None))
            continue
        tput = float(row.get("throughput_per_sec", 0.0))
        limit = floor * (1.0 - tolerance)
        verdict = "ok" if tput >= limit else "REGRESSION"
        print(f"{name:<44} {floor:>14.0f} {tput:>14.0f}  {verdict}")
        if tput < limit:
            regressions.append((name, floor, tput))
    for name in sorted(set(results) - set(floors)):
        tput = float(results[name].get("throughput_per_sec", 0.0))
        print(f"{name:<44} {'(no baseline)':>14} {tput:>14.0f}  new — consider adding")

    if regressions:
        for name, floor, tput in regressions:
            got = "not run" if tput is None else f"{tput:.0f}/s"
            print(
                f"::warning title=bench regression::{name}: {got} vs baseline "
                f"{floor:.0f}/s (>{tolerance:.0%} drop)"
            )
    else:
        print(f"bench gate: all within {tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
